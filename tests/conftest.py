"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only the dry-run subprocesses fake 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
