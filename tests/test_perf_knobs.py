"""Regression tests for the §Perf optimization knobs: every transform must
be numerically identity (head padding, KV repeat) or bounded-error with
argmax agreement (bf16 probs, int8 KV cache)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model_fns, transformer as TF


def _fwd(cfg, params, toks):
    out, _ = TF.lm_forward(params, toks, cfg, None)
    return out


@pytest.fixture(scope="module")
def gqa_setup():
    cfg0 = dataclasses.replace(
        get_smoke_config("deepseek-coder-33b"), dtype="float32"
    )
    fns = get_model_fns(cfg0)
    params = fns.init(jax.random.PRNGKey(1), cfg0)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, cfg0.vocab)
    return cfg0, params, toks, _fwd(cfg0, params, toks)


@pytest.mark.parametrize(
    "kw",
    [
        {"attn_pad_heads": 8},
        {"gqa_repeat_kv": True},
        {"attn_pad_heads": 8, "gqa_repeat_kv": True},
        {"attn_kv_chunk": 4},
    ],
)
def test_knob_is_identity(gqa_setup, kw):
    cfg0, params, toks, ref = gqa_setup
    cfg = dataclasses.replace(cfg0, **kw)
    got = _fwd(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), atol=5e-5, rtol=1e-4
    )


def test_bf16_probs_bounded_error(gqa_setup):
    cfg0, params, toks, ref = gqa_setup
    cfg = dataclasses.replace(cfg0, attn_probs_dtype="bfloat16")
    got = _fwd(cfg, params, toks)
    rel = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.03, rel
    assert bool(jnp.all(jnp.argmax(got, -1) == jnp.argmax(ref, -1)))


def test_int8_kv_cache_decode_close():
    cfg0 = dataclasses.replace(
        get_smoke_config("stablelm-3b"), dtype="float32"
    )
    cfg8 = dataclasses.replace(cfg0, kv_cache_dtype="int8")
    fns = get_model_fns(cfg8)
    params = fns.init(jax.random.PRNGKey(1), cfg8)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, cfg8.vocab)
    cache, lp = fns.prefill(params, {"tokens": toks[:, :-1]}, cfg8, 32)
    assert cache["k"].dtype == jnp.int8
    cache, ld = fns.decode_step(params, cache, toks[:, -1], cfg8)
    full, _ = TF.lm_forward(params, toks, cfg0, None)
    for got, want in ((lp, full[:, -2, :]), (ld, full[:, -1, :])):
        rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
        assert rel < 0.05, rel
        assert bool(jnp.all(jnp.argmax(got, -1) == jnp.argmax(want, -1)))


def test_int8_cache_halves_cache_bytes():
    # production head_dim (80): int8 + per-(pos,head) f32 scale ≈ 0.53×
    cfg0 = dataclasses.replace(get_smoke_config("stablelm-3b"), d_head=80)
    cfg8 = dataclasses.replace(cfg0, kv_cache_dtype="int8")
    c16 = TF.init_decode_cache(cfg0, batch=2, max_len=64)
    c8 = TF.init_decode_cache(cfg8, batch=2, max_len=64)
    bytes16 = sum(
        v.size * v.dtype.itemsize for k, v in c16.items() if k in ("k", "v")
    )
    bytes8 = sum(
        v.size * v.dtype.itemsize
        for k, v in c8.items()
        if k in ("k", "v", "k_scale", "v_scale")
    )
    assert bytes8 < 0.6 * bytes16  # int8 + scales ≈ 0.53×
