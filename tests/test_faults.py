"""Fault-injection suite: the shared step-fault helper (train + serving),
the serving FaultInjector's typed fault kinds, and the chaos fuzz — random
fault schedules over random traffic with allocator invariants re-checked
after EVERY engine tick.  The contract under every injected fault: the
engine keeps serving, the pool's safety invariants hold, and every
affected request ends with a typed ``done_reason``."""

import dataclasses
import random

import jax
import pytest

from _hypothesis_compat import hypothesis, st
from repro.configs import get_smoke_config
from repro.kernels.backend import FaultConfig
from repro.models import get_model_fns
from repro.serving import (
    EVICT_REASONS,
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    DegradationPolicy,
    FaultInjector,
    POOL_HOG_OWNER,
    RequestState,
    ServeConfig,
    ServingEngine,
)
from repro.testing import (
    FaultSchedule,
    InjectedFault,
    StepFaultInjector,
    fault_step_from_env,
)
from test_prefix_sharing import check_invariants

given = hypothesis.given
settings = hypothesis.settings


# ---------------------------------------------------------------------------
# Shared step-fault helper (repro.testing) — host logic, no model
# ---------------------------------------------------------------------------


def test_step_fault_injector_fires_exactly_once():
    inj = StepFaultInjector(3)
    assert inj.armed
    for step in (0, 1, 2):
        inj.check(step)
    with pytest.raises(InjectedFault, match="step 3"):
        inj.check(3)
    assert not inj.armed
    inj.check(3)  # a restarted loop re-runs the step without re-raising


def test_step_fault_injector_disarmed_by_default():
    inj = StepFaultInjector(None)
    assert not inj.armed
    for step in range(5):
        inj.check(step)


def test_fault_step_from_env(monkeypatch):
    monkeypatch.delenv("FAULT_INJECT_STEP", raising=False)
    assert fault_step_from_env(None) is None
    assert fault_step_from_env(7) == 7
    monkeypatch.setenv("FAULT_INJECT_STEP", "12")
    # explicit argument wins over the environment
    assert fault_step_from_env(7) == 7
    assert fault_step_from_env(None) == 12


def test_fault_schedule_pop_moves_to_fired():
    s = FaultSchedule().at(2, "a").at(2, "b", x=1).at(5, "c")
    assert bool(s) and s.pending == 3
    assert s.pop(0) == []
    evs = s.pop(2)
    assert [e.kind for e in evs] == ["a", "b"]
    assert evs[1].kwargs == {"x": 1}
    assert [e.kind for e in s.fired] == ["a", "b"]
    assert s.pending == 1
    s.pop(5)
    assert not s


def test_train_loop_uses_shared_injector():
    """The train loop's fault path now routes through repro.testing — the
    backward-compat alias must stay catchable as the shared type."""
    from repro.train.loop import _InjectedFault

    assert _InjectedFault is InjectedFault


# ---------------------------------------------------------------------------
# Serving FaultInjector: typed fault kinds (smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-3b")
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(smoke, injector, **kw):
    cfg, params = smoke
    base = dict(
        max_batch=2, max_new_tokens=6, max_len=64, kv_block_size=8,
        prefill_buckets=(16,),
    )
    base.update(kw)
    sc = ServeConfig(fault_injector=injector, **base)
    return ServingEngine(params, cfg, sc)


def test_exhaust_pool_backpressures_then_recovers(smoke):
    """With the pool held by the hog, nothing admits; releasing it lets
    the queued request through and it completes normally."""
    inj = FaultInjector().at(0, "exhaust_pool").at(3, "release_pool")
    eng = _engine(smoke, inj)
    rid = eng.submit(list(range(1, 10)), 6)
    eng.tick()
    req = eng.sched.request(rid)
    assert req.state is RequestState.QUEUED  # gate back-pressured
    assert eng.blocks.available == 0
    eng.run()
    assert req.done_reason == "length" and len(req.output) == 6
    assert ("exhaust_pool" in {k for _, k, _ in inj.applied})


def test_nan_logits_evicts_with_typed_reason(smoke):
    """The NaN guard: a poisoned read-window page makes the next decode
    step's logits non-finite and the engine evicts the victim with reason
    ``"nan"`` — the other slot keeps decoding to completion."""
    inj = FaultInjector().at(5, "nan_logits")
    eng = _engine(smoke, inj)
    ra = eng.submit(list(range(1, 10)), 20, priority=PRIORITY_BATCH)
    rb = eng.submit(list(range(40, 50)), 20)
    eng.run()
    victim = next(
        r for r in eng.sched.all_requests() if r.done_reason == "nan"
    )
    survivor = next(r for r in eng.sched.all_requests() if r is not victim)
    assert survivor.done_reason == "length"
    assert len(survivor.output) == 20
    assert eng.metrics().evictions["nan"] == 1
    assert eng.blocks.available == eng.blocks.capacity
    assert inj.applied[-1][1] == "nan_logits"


def test_deadline_storm_reaps_everything(smoke):
    inj = FaultInjector().at(2, "deadline_storm")
    eng = _engine(smoke, inj)
    rids = [
        eng.submit(list(range(1 + i, 10 + i)), 30) for i in range(3)
    ]
    eng.run()
    for rid in rids:
        assert eng.sched.request(rid).done_reason == "deadline"
    assert eng.metrics().evictions["deadline"] == 3
    assert eng.blocks.available == eng.blocks.capacity


def test_kill_prefill_frees_pages_and_sharers_recover(smoke):
    """Killing the FIFO-head prefill job mid-chunk drops its pipeline
    entry atomically; a queued sharer of its never-written pages demotes
    to recompute and still produces the solo-run token stream."""
    cfg, params = smoke
    prompt = list(range(1, 25))

    inj = FaultInjector().at(1, "kill_prefill")
    eng = _engine(smoke, inj, prefill_buckets=(32,), prefill_chunk=8,
                  max_new_tokens=4)
    ra = eng.submit(prompt, 4)
    rb = eng.submit(prompt, 4)
    eng.run()
    killed = eng.sched.request(ra)
    surv = eng.sched.request(rb)
    assert killed.done_reason == "preempted" and killed.output == []
    assert surv.done_reason == "length"
    assert eng.blocks.available == eng.blocks.capacity

    ref = _engine(smoke, None, prefill_buckets=(32,), prefill_chunk=8,
                  max_new_tokens=4)
    rc = ref.submit(prompt, 4)
    out = ref.run()
    assert surv.output == out[rc]


def test_every_eviction_reason_is_typed(smoke):
    """All reasons the engine can stamp are in the EVICT_REASONS registry
    (metrics consumers key on it)."""
    assert set(EVICT_REASONS) >= {
        "eos", "length", "deadline", "nan", "saturated",
        "entropy_collapse", "preempted",
    }


def test_unknown_fault_kind_rejected_at_schedule_time():
    """A typo'd kind must raise at .at() with the registered list — not
    as an AttributeError at fire time deep inside a chaos run."""
    with pytest.raises(ValueError, match="unknown fault kind 'nan_logit'"):
        FaultInjector().at(3, "nan_logit")
    try:
        FaultInjector().at(3, "nan_logit")
    except ValueError as e:
        # the loud part: the message enumerates every registered kind
        for kind in FaultInjector.kinds():
            assert kind in str(e)
    assert set(FaultInjector.kinds()) >= {
        "degrade_device", "recover_device", "nan_logits", "exhaust_pool",
    }


def test_degrade_device_noop_on_plain_backend(smoke):
    """degrade/recover_device on the plain sim backend (no degrade hook)
    must fire as a clean no-op so mixed schedules stay portable."""
    inj = (
        FaultInjector()
        .at(0, "degrade_device", comparator_offset=2.0)
        .at(1, "recover_device")
    )
    eng = _engine(smoke, inj)
    rid = eng.submit(list(range(1, 8)), 4)
    eng.run()
    assert eng.sched.request(rid).done_reason == "length"
    assert not inj.pending  # events fired...
    applied = {k for _, k, _ in inj.applied}
    assert "degrade_device" not in applied  # ...but applied nothing
    assert "recover_device" not in applied


# ---------------------------------------------------------------------------
# Chaos fuzz: random fault schedules over random traffic
# ---------------------------------------------------------------------------

_FAULT_KINDS = (
    "exhaust_pool", "release_pool", "nan_logits", "deadline_storm",
    "kill_prefill", "preempt", "degrade_device", "recover_device",
)


def _chaos_trace(smoke, seed: int, faulty: bool = False) -> None:
    rng = random.Random(seed)
    inj = FaultInjector()
    for _ in range(rng.randint(2, 6)):
        kind = rng.choice(_FAULT_KINDS)
        kw = {}
        if kind == "degrade_device":
            kw = dict(comparator_offset=rng.choice((0.0, 2.0)))
        inj.at(rng.randint(0, 20), kind, **kw)
    # a released pool hog + recovered device at the end so the drain
    # below can finish (a stuck degradation ladder at level 3 sheds
    # batch admissions forever)
    inj.at(21, "release_pool").at(21, "recover_device")
    fault_kw = {}
    if faulty:
        # the analog device-fault storm rides on top: seeded stuck
        # cells from tick 0, a per-2-ticks canary with tile
        # retirement, and the full degradation ladder armed
        fault_kw = dict(
            device_backend="sim_faulty",
            device_fault_config=FaultConfig(seed=seed, stuck_rate=0.02),
            canary_interval=2,
            tile_retire_threshold=0.01,
            degradation=DegradationPolicy(),
        )
    eng = _engine(
        smoke, inj,
        prefill_buckets=(16, 32),
        prefill_chunk=rng.choice((0, 8)),
        num_kv_blocks=rng.choice((0, 9)),
        max_new_tokens=8,
        # speculative rounds must survive the same storm: draft-depth NaN
        # guard, preempting a speculating slot, rollback under pressure
        speculate_k=rng.choice((0, 2, 3)),
        **fault_kw,
    )
    rids = []
    for tick in range(24):
        if rng.random() < 0.5 and len(rids) < 6:
            n = rng.randint(1, 20)
            rids.append(
                eng.submit(
                    list(range(1, n + 1)), rng.randint(1, 8),
                    priority=rng.choice(
                        (PRIORITY_INTERACTIVE, PRIORITY_BATCH)
                    ),
                    deadline_ms=rng.choice((None, 10_000.0)),
                )
            )
        eng.tick()
        check_invariants(eng.blocks)
        # the hog keeps its reservation between exhaust/release events;
        # every OTHER owner must be a live request or a pipeline job
        live = {
            r.rid
            for r in eng.sched.all_requests()
            if r.state is not RequestState.DONE
        }
        for owner in eng.blocks._owned:
            assert owner == POOL_HOG_OWNER or owner in live
    # drain: the engine must still be serviceable after the storm
    n = 0
    while eng.sched.has_work() and n < 400:
        eng.tick()
        check_invariants(eng.blocks)
        n += 1
    assert not eng.sched.has_work(), "engine wedged after fault storm"
    for rid in rids:
        req = eng.sched.request(rid)
        assert req.state is RequestState.DONE
        assert req.done_reason in EVICT_REASONS, req.done_reason
    assert eng.blocks.available == eng.blocks.capacity


@settings(deadline=None, max_examples=3)
@given(seed=st.integers(0, 10_000))
def test_chaos_fuzz_invariants_every_tick(smoke, seed):
    _chaos_trace(smoke, seed)


@settings(deadline=None, max_examples=2)
@given(seed=st.integers(0, 10_000))
def test_chaos_fuzz_faulty_device_backend(smoke, seed):
    """The same never-crash contract with analog device faults live: the
    sim_faulty backend at a nonzero stuck-cell rate, canary probes, tile
    retirement and the degradation ladder all running under the random
    fault storm."""
    _chaos_trace(smoke, seed, faulty=True)
