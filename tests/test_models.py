"""Per-architecture smoke + decode-consistency tests (reduced configs)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config, SHAPES
from repro.configs import skip_shapes
from repro.models import get_model_fns, transformer as TF
from repro.core.analog import AnalogConfig


def _batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(key, (b, s, cfg.d_model)),
            "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
    out = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model)
        )
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward/train step on CPU,
    output shapes + no NaNs (assignment requirement)."""
    cfg = get_smoke_config(arch)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    loss, metrics = fns.loss(params, batch, cfg, jax.random.PRNGKey(2))
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: fns.loss(p, batch, cfg, None)[0])(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_match_forward(arch):
    """Prefill logits and one decode step must equal the full forward —
    bit-exactly (same dtypes, same conv/rounding paths)."""
    cfg = get_smoke_config(arch)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 12
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.family == "encdec":
        from repro.models import encdec as ED

        frames = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
        cache, lp = fns.prefill(
            params, {"frames": frames, "tokens": toks[:, :-1]}, cfg, 32
        )
        cache, ld = fns.decode_step(params, cache, toks[:, -1], cfg)
        enc = ED.encode(params, frames, cfg)
        full = ED.decode_train(params, toks, enc, cfg)
    else:
        batch = {"tokens": toks[:, :-1]}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                key, (b, cfg.n_patches, cfg.d_model)
            )
        cache, lp = fns.prefill(params, batch, cfg, 32)
        cache, ld = fns.decode_step(params, cache, toks[:, -1], cfg)
        full, _ = TF.lm_forward(params, toks, cfg, None, batch.get("patches"))
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(full[:, -2, :]))
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(full[:, -1, :]))


def test_full_configs_match_assignment():
    """The exact assigned numbers, verbatim."""
    spec = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "whisper-small": (24, 768, 12, 12, 3072, 51865),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == l, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    m = get_config("mamba2-1.3b")
    assert (m.n_layers, m.d_model, m.vocab, m.ssm_state) == (
        48, 2048, 50280, 128,
    )
    g = get_config("granite-moe-3b-a800m")
    assert (g.n_experts, g.moe_topk) == (40, 8)
    k = get_config("grok-1-314b")
    assert (k.n_experts, k.moe_topk) == (8, 2)


def test_param_counts_plausible():
    """Headline sizes should land near the advertised scales."""
    expect = {
        "nemotron-4-340b": (340e9, 0.10),
        "grok-1-314b": (314e9, 0.10),
        "deepseek-coder-33b": (33e9, 0.15),
        "gemma2-2b": (2.6e9, 0.25),
        "mamba2-1.3b": (1.3e9, 0.35),
    }
    for arch, (n, tol) in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < tol, (arch, got)


def test_long500k_skips_are_only_subquadratic():
    runs_500k = [
        a for a in ASSIGNED_ARCHS if "long_500k" not in skip_shapes(a)
    ]
    assert sorted(runs_500k) == ["mamba2-1.3b", "recurrentgemma-2b"]


def test_analog_stochastic_mode_trains():
    """RACA integration: stablelm smoke with analog MLP + stochastic neurons
    takes a gradient step without NaNs (the QAT path)."""
    from repro.core.physics import DeviceParams, calibrate_v_read

    base = get_smoke_config("stablelm-3b")
    cfg = dataclasses.replace(
        base,
        analog=AnalogConfig(
            mode="analog_stochastic",
            device=calibrate_v_read(DeviceParams(), base.d_model),
            use_pallas="off",
        ),
        dtype="float32",
    )
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    loss, _ = fns.loss(params, batch, cfg, jax.random.PRNGKey(3))
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: fns.loss(p, batch, cfg, jax.random.PRNGKey(3))[0])(
        params
    )
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_moe_no_drop_equals_dense_topk():
    """With ample capacity, grouped dispatch == explicit per-token top-k
    mixture (the semantics oracle)."""
    from repro.models import moe as MOE

    cfg = dataclasses.replace(
        get_smoke_config("grok-1-314b"), capacity_factor=8.0, dtype="float32"
    )
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, _ = MOE.moe_apply(p, x, cfg, None)

    # oracle: dense evaluation of every expert, weighted by top-k gates
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.moe_topk)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"][e])
        gt = jnp.einsum("bsd,df->bsf", x, p["w_gate"][e])
        h = jax.nn.gelu(gt, approximate=True) * up
        outs.append(jnp.einsum("bsf,fd->bsd", h, p["w_down"][e]))
    dense = jnp.stack(outs, axis=2)  # (B,S,E,D)
    want = jnp.zeros_like(x)
    for j in range(cfg.moe_topk):
        want = want + gates[..., j : j + 1] * jnp.take_along_axis(
            dense, ids[..., j][..., None, None], axis=2
        )[..., 0, :]
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(want), atol=2e-4, rtol=1e-3
    )
