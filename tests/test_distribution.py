"""Distribution layer: sharding rules, small-mesh lowering (subprocess with
fake devices), elastic checkpoint reshard, serving engine."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as SH
from repro.launch import roofline as RL

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_divisibility_guard_drops_axes():
    """heads=56 is not divisible by model=16 → replicated, not padded."""

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    cfg = get_config("deepseek-coder-33b")
    rules = SH.activation_rules(FakeMesh(), cfg, 256)
    assert rules["heads"] is None          # 56 % 16 != 0
    assert rules["kv_heads"] is None       # 8 % 16 != 0
    assert rules["ffn"] == "model"
    assert rules["batch"] == ("data",)
    cfg2 = get_config("stablelm-3b")
    rules2 = SH.activation_rules(FakeMesh(), cfg2, 256)
    assert rules2["heads"] == "model"      # 32 % 16 == 0


def test_batch_axes_adapt_to_batch_size():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        devices = np.empty((2, 16, 16))

    assert SH.batch_axes(FakeMesh(), 256) == ("pod", "data")
    assert SH.batch_axes(FakeMesh(), 1) is None
    assert SH.batch_axes(FakeMesh(), 2) == ("pod",)


def test_param_rules_shard_big_models_fsdp():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("nemotron-4-340b")  # > FSDP threshold
    # stacked scanned-unit param: (n_units, D, F)
    sds = jax.ShapeDtypeStruct((96, 18432, 73728), jnp.bfloat16)
    sh = SH.param_shardings({"units": {"l0": {"ffn": {"w_up": sds}}}},
                            mesh, cfg)
    spec = sh["units"]["l0"]["ffn"]["w_up"].spec
    # leading scan axis None; D -> data (fsdp), F -> model
    assert spec == P(None, "data", "model")


def test_small_mesh_lowering_subprocess():
    """End-to-end dry-run machinery on an 8-device fake mesh (train +
    decode), in a subprocess so the main process keeps 1 device."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from repro import parallel
        from repro.configs import get_smoke_config
        from repro.launch import sharding as SH, specs as SP
        from repro.train import TrainConfig, make_train_step
        from repro.configs.shapes import ShapeSpec

        cfg = get_smoke_config("gemma2-2b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shape = ShapeSpec("t", 32, 8, "train")
        rules = SH.activation_rules(mesh, cfg, 8)
        with parallel.axis_rules(mesh, rules):
            tcfg = TrainConfig()
            st = SP.train_state_specs(cfg, tcfg)
            ssh = SH.state_shardings(st, mesh, cfg)
            bs = SP.train_batch_specs(cfg, shape)
            bsh = SH.batch_shardings(bs, mesh, 8)
            step = make_train_step(cfg, tcfg)
            c = jax.jit(step, in_shardings=(ssh, bsh),
                        out_shardings=(ssh, None),
                        donate_argnums=(0,)).lower(st, bs).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # jax<0.5
            print("TRAIN_OK", ca.get("flops", 0) > 0)

            dshape = ShapeSpec("d", 64, 8, "decode")
            ps = SP.params_specs(cfg)
            psh = SH.param_shardings(ps, mesh, cfg)
            cs = SP.decode_cache_specs(cfg, dshape)
            csh = SH.cache_shardings(cs, mesh, cfg, 8)
            from jax.sharding import NamedSharding, PartitionSpec as P
            tok = NamedSharding(mesh, P(SH.batch_axes(mesh, 8)))
            serve = SP.make_serve_step(cfg)
            c2 = jax.jit(serve, in_shardings=(psh, csh, tok),
                         out_shardings=(csh, tok),
                         donate_argnums=(1,)).lower(
                ps, cs, jax.ShapeDtypeStruct((8,), jnp.int32)).compile()
            print("DECODE_OK")
    """)
    assert "TRAIN_OK True" in out
    assert "DECODE_OK" in out


def test_elastic_checkpoint_reshard_subprocess(tmp_path):
    """Save on a 1-device run, restore sharded onto a fake 8-device mesh —
    the elastic-restart path."""
    from repro.checkpoint import save_checkpoint
    from repro.train import TrainConfig, init_train_state

    cfg = get_smoke_config("stablelm-3b")
    tcfg = TrainConfig()
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    save_checkpoint(str(tmp_path), 3, state)

    out = _run_sub(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.checkpoint import load_checkpoint
        from repro.configs import get_smoke_config
        from repro.launch import sharding as SH
        from repro.train import TrainConfig, init_train_state

        cfg = get_smoke_config("stablelm-3b")
        tcfg = TrainConfig()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        like = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
        sh = SH.state_shardings(like, mesh, cfg)
        st = load_checkpoint({str(tmp_path)!r}, 3, like, shardings=sh)
        w = st.params["units"]["l0"]["ffn"]["w_up"]
        print("RESHARD_OK", int(st.step), w.sharding.spec)
    """)
    # restored onto the new mesh with the F axis model-sharded
    assert "RESHARD_OK 0" in out
    assert "'model'" in out


def test_roofline_collective_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[2,1024]{1,0} %p), dims={0}
  %ar.1 = f32[512]{0} all-reduce(f32[512]{0} %x), to_apply=%sum
  %rs = f32[64]{0} reduce-scatter(f32[512]{0} %y), dimensions={0}
  %a2a = (f32[8,32]{1,0}) all-to-all(f32[8,32]{1,0} %z)
  %done = bf16[4]{0} all-gather-done(bf16[4]{0} %t)
"""
    c = RL.parse_collectives(hlo)
    assert c["all-gather"] == 16 * 1024 * 2
    assert c["all-reduce"] == 2 * 512 * 4
    assert c["reduce-scatter"] == 512 * 4
    assert c["all-to-all"] == 8 * 32 * 4
    assert c["count"] == 4  # -done is not a transfer


def test_roofline_dominant_term_tie_break():
    """Tied times must resolve by listed order (compute first), never by
    comparing the label strings ("memory" > "compute" alphabetically —
    the bug a key-less tuple max had)."""
    def rec(flops, bytes_, coll):
        return {
            "cost": {"flops": flops, "bytes accessed": bytes_},
            "collectives": {"total_bytes": coll},
            "model_flops_per_chip": flops,
        }

    # exact three-way tie: equal times for all terms → compute wins
    f = RL.PEAK_FLOPS
    t = RL.roofline_terms(rec(f, RL.HBM_BW, RL.ICI_BW))
    assert t["compute_s"] == t["memory_s"] == t["collective_s"]
    assert t["dominant"] == "compute"
    # compute/memory tie with collectives below → still compute
    t = RL.roofline_terms(rec(f, RL.HBM_BW, 0.0))
    assert t["dominant"] == "compute"
    # untied cases keep picking the true max
    assert RL.roofline_terms(rec(f, 3 * RL.HBM_BW, 0.0))[
        "dominant"
    ] == "memory"
    assert RL.roofline_terms(rec(f, 0.0, 3 * RL.ICI_BW))[
        "dominant"
    ] == "collective"


def test_serving_engine_generates():
    from repro.serving import ServeConfig, ServingEngine
    from repro.models import get_model_fns

    cfg = get_smoke_config("stablelm-3b")
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(max_new_tokens=5,
                                                 max_len=32))
    eng.submit([5, 6, 7])
    eng.submit([1, 2, 3, 4])
    outs = eng.step()
    assert len(outs) == 2
    assert all(len(o) == 5 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_serving_wta_head_runs():
    import dataclasses
    from repro.serving import ServeConfig, ServingEngine
    from repro.models import get_model_fns

    cfg = dataclasses.replace(get_smoke_config("stablelm-3b"),
                              wta_head=True)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, ServeConfig(max_new_tokens=3,
                                                 max_len=32))
    eng.submit([5, 6, 7])
    outs = eng.step()
    assert len(outs[0]) == 3


def test_paged_pool_partition_specs_on_fake_mesh():
    """Directed check of the paged-pool name rules on a (data=2, model=2)
    mesh: pool pages shard over data + kv_heads over model (stablelm
    smoke, kv_heads=4), and the kv_heads axis REPLICATES when model does
    not divide it — never GSPMD padding."""
    from repro.launch import specs as SP

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 2))

    cfg = get_smoke_config("stablelm-3b")  # kv_heads=4: divisible by 2
    sds = SP.paged_decode_cache_specs(cfg, batch=4, n_pages=8, block_size=8)
    specs = SH.cache_partition_specs(sds, FakeMesh(), cfg, 4)
    # (nu, n_attn, n_pages, block, Hkv, Dh)
    assert specs["k_pages"] == P(None, None, "data", None, "model", None)
    assert specs["v_pages"] == P(None, None, "data", None, "model", None)
    assert specs["pos"] == P(("data",))
    # int8 layout: scale planes follow their code pages
    import dataclasses
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    sds8 = SP.paged_decode_cache_specs(cfg8, batch=4, n_pages=8,
                                       block_size=8)
    specs8 = SH.cache_partition_specs(sds8, FakeMesh(), cfg8, 4)
    assert specs8["k_pages"] == P(None, None, "data", None, "model", None)
    assert specs8["k_scale_pages"] == P(None, None, "data", None, "model")
    assert specs8["v_scale_pages"] == P(None, None, "data", None, "model")
    assert specs8["quant_step"] == P()
    # kv_heads=1 (recurrentgemma smoke) % model=2 != 0 → heads replicate,
    # pages still shard over data
    cfg1 = get_smoke_config("recurrentgemma-2b")
    sds1 = SP.paged_decode_cache_specs(cfg1, batch=4, n_pages=8,
                                       block_size=8)
    specs1 = SH.cache_partition_specs(sds1, FakeMesh(), cfg1, 4)
    assert specs1["k_pages"] == P(None, None, "data", None, None, None)
    # a pool whose page count the data axis does not divide replicates
    sds_odd = SP.paged_decode_cache_specs(cfg, batch=4, n_pages=7,
                                          block_size=8)
    specs_odd = SH.cache_partition_specs(sds_odd, FakeMesh(), cfg, 4)
    assert specs_odd["k_pages"] == P(None, None, None, None, "model", None)


def test_sharded_engine_token_identity_subprocess():
    """Sharded-vs-unsharded token identity on real multi-device meshes
    (4 fake host devices): the full continuous-batching trace through a
    (1, model) mesh — the ISSUE's kv_heads-divisible contract — plus a
    (2, 2) mesh admission-capacity check of the data-axis pool scaling."""
    out = _run_sub("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        from repro.configs import get_smoke_config
        from repro.models import get_model_fns
        from repro.serving import RequestState, ServeConfig, ServingEngine
        from repro.launch.mesh import make_host_mesh

        cfg = get_smoke_config("stablelm-3b")  # kv_heads=4: model-divisible
        params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
        prompts = [
            [163, 131, 69, 79, 11, 20, 5, 45],
            [166, 233, 129, 155, 248, 187, 162, 139],
            [239, 71, 209, 172, 1, 101],
            [142, 9, 196, 187, 216, 45, 23, 221],
        ]

        def run(mesh):
            eng = ServingEngine(params, cfg, ServeConfig(
                max_batch=2, max_new_tokens=8, max_len=64,
                kv_layout="paged", kv_block_size=8, mesh=mesh))
            for p in prompts:
                eng.submit(list(p))
            return eng.step()

        base = run(None)
        print("MODEL_MESH_OK", base == run(make_host_mesh(model=4, data=1)))
        print("DATA_MESH_OK", base == run(make_host_mesh(model=1, data=2)))
        print("GRID_MESH_OK", base == run(make_host_mesh(model=2, data=2)))

        # data-axis capacity: per-device budget 8 blocks, (2, 2) mesh pool
        # holds 16 pages at the same bytes per device
        def admitted(mesh, blocks):
            eng = ServingEngine(params, cfg, ServeConfig(
                max_batch=16, max_new_tokens=8, max_len=64,
                kv_layout="paged", kv_block_size=8, num_kv_blocks=blocks,
                enable_prefix_sharing=False, mesh=mesh))
            for _ in range(16):
                eng.submit([1, 2, 3], 8)
            eng.tick()
            return sum(1 for r in eng.sched.all_requests()
                       if r.state is not RequestState.QUEUED)

        single = admitted(None, 8)
        sharded = admitted(make_host_mesh(model=2, data=2), 16)
        print("CAPACITY_OK", sharded > single, single, sharded)
    """)
    assert "MODEL_MESH_OK True" in out
    assert "DATA_MESH_OK True" in out
    assert "GRID_MESH_OK True" in out
    assert "CAPACITY_OK True" in out
