"""Table I reproduction (paper §IV-C)."""

import numpy as np

from repro.core import cost_model as CM


def test_table1_reproduces_paper():
    t = CM.table1()
    p = CM.PAPER_TABLE1
    assert abs(t["adc1b"].energy_pj - p["adc1b"].energy_pj) / p[
        "adc1b"
    ].energy_pj < 0.005
    assert abs(t["raca"].energy_pj - p["raca"].energy_pj) / p[
        "raca"
    ].energy_pj < 0.005
    assert abs(t["adc1b"].area_mm2 - p["adc1b"].area_mm2) < 0.05
    assert abs(t["raca"].area_mm2 - p["raca"].area_mm2) < 0.05
    # the paper's headline deltas, within half a point
    assert abs(t["energy_change_pct"] - (-58.29)) < 0.5
    assert abs(t["area_change_pct"] - (-38.43)) < 0.5
    assert abs(t["efficiency_change_pct"] - 142.37) < 0.5


def test_raca_wins_scale_with_depth():
    """The model generalizes: deeper FCNNs keep the energy advantage."""
    layers = (784, 512, 512, 512, 10)
    a = CM.cost_adc1b(layers)
    r = CM.cost_raca(layers)
    assert r.energy_pj < a.energy_pj
    assert r.area_mm2 < a.area_mm2
    assert r.tops_per_w > a.tops_per_w


def test_comparator_cheaper_than_adc():
    assert CM.E_CMP < CM.E_ADC
    assert CM.A_CMP < CM.A_ADC
