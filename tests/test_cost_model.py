"""Table I reproduction (paper §IV-C) + served-traffic analog accounting."""

import dataclasses

import pytest

from repro.configs import get_smoke_config
from repro.core import cost_model as CM


def test_table1_reproduces_paper():
    t = CM.table1()
    p = CM.PAPER_TABLE1
    # tight tolerances: with A_ADC calibrated against the same ceil'd
    # shared-unit count cost_adc1b charges (592 = ceil(4730/8)), the
    # model lands within 1e-3 of every paper cell, not just 5e-3
    assert abs(t["adc1b"].energy_pj - p["adc1b"].energy_pj) / p[
        "adc1b"
    ].energy_pj < 1e-3
    assert abs(t["raca"].energy_pj - p["raca"].energy_pj) / p[
        "raca"
    ].energy_pj < 1e-3
    assert abs(t["adc1b"].area_mm2 - p["adc1b"].area_mm2) < 1e-3
    assert abs(t["raca"].area_mm2 - p["raca"].area_mm2) < 1e-3
    # the paper's headline deltas, within a tenth of a point
    assert abs(t["energy_change_pct"] - (-58.29)) < 0.1
    assert abs(t["area_change_pct"] - (-38.43)) < 0.1
    assert abs(t["efficiency_change_pct"] - 142.37) < 0.1


def test_raca_wins_scale_with_depth():
    """The model generalizes: deeper FCNNs keep the energy advantage."""
    layers = (784, 512, 512, 512, 10)
    a = CM.cost_adc1b(layers)
    r = CM.cost_raca(layers)
    assert r.energy_pj < a.energy_pj
    assert r.area_mm2 < a.area_mm2
    assert r.tops_per_w > a.tops_per_w


def test_comparator_cheaper_than_adc():
    assert CM.E_CMP < CM.E_ADC
    assert CM.A_CMP < CM.A_ADC


# -- served-traffic accounting (AnalogOpCounts + pricing) -------------------


def test_analog_op_counts_arithmetic_and_roundtrip():
    a = CM.AnalogOpCounts(macs=3, tile_reads=2, comparator_decisions=5)
    b = CM.AnalogOpCounts(macs=1, dac_conversions=7)
    s = a + b
    assert s.macs == 4 and s.tile_reads == 2 and s.dac_conversions == 7
    assert s.scaled(3).macs == 12
    assert s.scaled(0) == CM.AnalogOpCounts()
    # dict round-trip is exact (the reconciliation path in
    # validate_report rebuilds counts from the JSON artifact)
    assert CM.AnalogOpCounts.from_dict(s.as_dict()) == s


def test_per_token_counts_match_hand_derivation():
    """Pin the per-token counts for one small attention config against a
    by-hand enumeration of its weight matmuls."""
    cfg = get_smoke_config("stablelm-3b")
    mm = CM.per_token_weight_matmuls(cfg)
    # every layer: wq, wk, wv, wo + FFN (w_up, w_down [+ w_gate]); plus
    # the LM head
    per_layer = 4 + (3 if cfg.mlp in ("swiglu", "geglu") else 2)
    assert len(mm) == cfg.n_layers * per_layer + 1
    c = CM.per_token_analog_counts(cfg)
    macs = sum(k * n for k, n in mm)
    tiles = sum(-(-k // CM.ARRAY_ROWS) * n for k, n in mm)
    assert c.macs == macs
    assert c.tile_reads == tiles
    assert c.comparator_decisions == CM.RACA_TRIALS * sum(
        n for _, n in mm
    )
    # input DACs: RACA drives the d_model input stage once per token;
    # the ADC baseline re-converts every matmul input at INPUT_BITS
    assert c.dac_conversions == CM.RACA_TRIALS * cfg.d_model
    assert c.adc1b_dac_conversions == CM.INPUT_BITS * sum(
        k for k, _ in mm
    )
    assert c.adc1b_adc_conversions == CM.INPUT_BITS * tiles


def test_sampling_and_kv_round_counts():
    cfg = get_smoke_config("stablelm-3b")
    # greedy digital argmax: zero analog sampling work
    assert CM.per_sample_analog_counts(cfg) == CM.AnalogOpCounts()
    wta = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    s = CM.per_sample_analog_counts(wta)
    assert s.comparator_decisions == 8 * cfg.vocab
    assert s.wta_samples == 1
    # stochastic rounding happens only for int8 KV writes: 2 tensors
    # (K and V) x attention layers x kv_heads x head_dim
    assert CM.per_kv_token_round_events(cfg) == CM.AnalogOpCounts()
    i8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    r = CM.per_kv_token_round_events(i8)
    n_attn = sum(
        1 for k in (cfg.layer_pattern * cfg.n_layers)[: cfg.n_layers]
        if k in ("attn", "global", "local")
    )
    assert r.stoch_round_events == 2 * n_attn * cfg.n_kv_heads * cfg.d_head


def test_pricing_raca_below_adc1b():
    """For any real per-token event stream the ADC-free readout prices
    strictly below the 1-bit-ADC baseline — the inequality
    validate_report enforces on the committed serving artifact."""
    cfg = get_smoke_config("stablelm-3b")
    c = CM.per_token_analog_counts(cfg)
    p = CM.price_counts(c)
    assert 0 < p["raca_energy_pj"] < p["adc1b_energy_pj"]
    # TOPS/W moves the other way, and zero-energy input is guarded
    assert CM.effective_tops_per_w(c, p["raca_energy_pj"]) > (
        CM.effective_tops_per_w(c, p["adc1b_energy_pj"])
    )
    zero = CM.AnalogOpCounts()
    zp = CM.price_counts(zero)
    assert zp["raca_energy_pj"] == 0.0
    assert CM.effective_tops_per_w(zero, 0.0) == 0.0


def test_unknown_family_layer_raises():
    cfg = get_smoke_config("stablelm-3b")
    bad = dataclasses.replace(cfg, layer_pattern=("nope",))
    with pytest.raises((ValueError, KeyError)):
        CM.per_token_weight_matmuls(bad)
