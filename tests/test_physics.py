"""Unit + property tests for the device physics (paper Eq. 1-7, 13)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import crossbar, physics

DP = physics.DeviceParams()


def test_weight_mapping_constants():
    # Eq. 4/5 closed forms for the symmetric default range
    assert np.isclose(DP.g0, (DP.g_max - DP.g_min) / 2.0)
    assert np.isclose(DP.g_ref, (DP.g_max + DP.g_min) / 2.0)


def test_mapping_roundtrip_exact_without_quantization():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    m = crossbar.map_weights(w, DP, quantize=False)
    # absolute tolerance: W -> G -> W round-trips through f32 with a large
    # additive G_ref, so cancellation limits small-weight precision (this IS
    # the physical programming-precision limit)
    np.testing.assert_allclose(
        np.asarray(m.w_eff), np.asarray(w), atol=5e-4, rtol=1e-3
    )
    # Eq. 7: G = W·G0 + Gref
    np.testing.assert_allclose(
        np.asarray(m.g), np.asarray(w) * DP.g0 + DP.g_ref, rtol=1e-6
    )


def test_quantization_grid():
    w = jax.random.normal(jax.random.PRNGKey(1), (100, 10))
    wq = crossbar.quantize_weights(w, DP)
    step = (DP.w_max - DP.w_min) / (DP.n_levels - 1)
    lv = (np.asarray(wq) - DP.w_min) / step
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-4)
    assert np.abs(np.asarray(wq) - np.clip(np.asarray(w), -1, 1)).max() <= (
        step / 2 + 1e-6
    )


def test_differential_mac_mean_is_exact():
    """Eq. 12: E[I_j - I_ref] = Vr·G0·Σ W x (noise off via huge SNR)."""
    dp = DP.replace(delta_f=1e-30)  # kill noise
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (128, 16)) * 0.2
    x = jax.random.uniform(jax.random.PRNGKey(3), (8, 128))
    m = crossbar.map_weights(w, dp, quantize=False)
    delta, _ = crossbar.analog_mac(jax.random.PRNGKey(4), x, m, dp)
    expected = dp.v_read * dp.g0 * (np.asarray(x) @ np.asarray(w))
    np.testing.assert_allclose(np.asarray(delta), expected, rtol=2e-4)


def test_calibration_gives_unit_beta():
    for n_rows, beta in [(784, 1.0), (256, 1.0), (1024, 2.0)]:
        dp = physics.calibrate_v_read(DP, n_rows, beta=beta)
        assert np.isclose(physics.effective_beta(dp, n_rows), beta, rtol=1e-6)


@hypothesis.given(
    g=st.floats(1e-7, 1e-3),
    df=st.floats(1e6, 1e12),
    t=st.floats(200.0, 400.0),
)
@hypothesis.settings(deadline=None, max_examples=50)
def test_nyquist_scaling(g, df, t):
    """Eq. 1: i_RMS = sqrt(4kTGΔf) — exact scaling law."""
    dp = DP.replace(delta_f=df, temperature=t)
    i1 = float(physics.thermal_noise_rms(jnp.asarray(g), dp))
    i2 = float(physics.thermal_noise_rms(jnp.asarray(4 * g), dp))
    assert np.isclose(i2, 2 * i1, rtol=1e-6)  # ∝ sqrt(G)
    dp2 = dp.replace(delta_f=4 * df)
    i3 = float(physics.thermal_noise_rms(jnp.asarray(g), dp2))
    assert np.isclose(i3, 2 * i1, rtol=1e-6)  # ∝ sqrt(Δf)
    expected = np.sqrt(4 * physics.BOLTZMANN_K * t * g * df)
    assert np.isclose(i1, expected, rtol=1e-6)


def test_snr_knobs_move_effective_beta():
    """Fig. 4(c)-(f): Vr, G0 (via range), Δf and N_col all tune the SNR."""
    base = physics.calibrate_v_read(DP, 512)
    b0 = physics.effective_beta(base, 512)
    assert physics.effective_beta(base.replace(v_read=base.v_read * 2), 512) > b0
    assert physics.effective_beta(base.replace(delta_f=base.delta_f * 4), 512) < b0
    assert physics.effective_beta(base, 2048) < b0  # more rows -> more noise
    wider = base.replace(g_max=base.g_max * 2)  # larger G0
    assert physics.effective_beta(wider, 512) > b0


@hypothesis.given(st.integers(16, 2048))
@hypothesis.settings(deadline=None, max_examples=20)
def test_column_noise_additivity(n_rows):
    """Column noise variance is the SUM of device variances (Eq. 11)."""
    sum_g = jnp.asarray(n_rows * 2.0 * DP.g_ref)
    sigma = float(physics.column_noise_sigma(sum_g, DP))
    one = float(physics.column_noise_sigma(jnp.asarray(2.0 * DP.g_ref), DP))
    assert np.isclose(sigma, one * np.sqrt(n_rows), rtol=1e-5)
