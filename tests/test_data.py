"""Data pipeline invariants: determinism, shard disjointness, learnability."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import lm_batch, mnist_batch, mnist_dataset
from repro.data.mnist_synth import _GLYPH_ARR


CFG = get_smoke_config("stablelm-3b")


def test_lm_batch_deterministic():
    a = lm_batch(CFG, batch=4, seq=32, step=5)
    b = lm_batch(CFG, batch=4, seq=32, step=5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_lm_batch_steps_and_shards_differ():
    a = lm_batch(CFG, batch=4, seq=32, step=1)
    b = lm_batch(CFG, batch=4, seq=32, step=2)
    c = lm_batch(CFG, batch=4, seq=32, step=1, shard=1)
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_labels_are_next_tokens():
    a = lm_batch(CFG, batch=2, seq=16, step=0)
    # labels[t] is the token following tokens[t] in the same stream
    assert a["tokens"].shape == a["labels"].shape
    np.testing.assert_array_equal(
        np.asarray(a["tokens"][:, 1:]), np.asarray(a["labels"][:, :-1])
    )


def test_lm_stream_is_learnable():
    """Markov structure: successor rule holds ~markov_p of the time."""
    a = lm_batch(CFG, batch=16, seq=256, step=0)
    toks = np.asarray(a["tokens"])
    succ = (toks[:, :-1] * 31 + 17) % CFG.vocab
    rate = (succ == toks[:, 1:]).mean()
    assert 0.6 < rate < 0.9, rate


def test_mnist_deterministic_and_ranged():
    a = mnist_batch(batch=8, step=3)
    b = mnist_batch(batch=8, step=3)
    np.testing.assert_array_equal(np.asarray(a["image"]),
                                  np.asarray(b["image"]))
    img = np.asarray(a["image"])
    assert img.min() >= 0.0 and img.max() <= 1.0
    assert img.shape == (8, 784)


def test_mnist_classes_are_distinguishable():
    """Nearest-class-centroid on raw pixels must beat chance comfortably —
    the surrogate task is real but not trivial."""
    train = mnist_dataset(2000, seed=7)
    test = mnist_dataset(500, seed=8)
    xtr = np.asarray(train["image"]); ytr = np.asarray(train["label"])
    xte = np.asarray(test["image"]); yte = np.asarray(test["label"])
    cents = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
    pred = np.argmin(
        ((xte[:, None, :] - cents[None]) ** 2).sum(-1), axis=1
    )
    acc = (pred == yte).mean()
    assert acc > 0.5, acc


def test_mnist_glyphs_cover_all_digits():
    assert _GLYPH_ARR.shape == (10, 7, 5)
    # all glyphs distinct
    flat = np.asarray(_GLYPH_ARR).reshape(10, -1)
    for i in range(10):
        for j in range(i + 1, 10):
            assert (flat[i] != flat[j]).any(), (i, j)
