"""Shape contracts for the serving specs layer: `decode_cache_specs`,
`make_serve_step`, and the slot-addressable cache insert.  Cache-layout
refactors must fail HERE, loudly, instead of surfacing as silent XLA
recompiles or wrong-slot writes in the serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.launch import specs as SP

B, S = 4, 32
DSHAPE = ShapeSpec("d", S, B, "decode")


def _tree_specs(tree):
    return jax.tree.map(lambda l: (tuple(l.shape), jnp.dtype(l.dtype)), tree)


@pytest.mark.parametrize(
    "arch", ["stablelm-3b", "recurrentgemma-2b", "mamba2-1.3b"]
)
def test_decode_cache_batch_axis_contract(arch):
    """Every cache leaf carries the request/slot axis where
    `cache_batch_axis` says it is — the invariant slot insertion needs."""
    cfg = get_smoke_config(arch)
    specs = SP.decode_cache_specs(cfg, DSHAPE)
    assert "pos" in specs
    assert specs["pos"].shape == (B,)
    assert specs["pos"].dtype == jnp.int32
    for name, leaf in specs.items():
        ax = SP.cache_batch_axis(cfg, name)
        assert leaf.shape[ax] == B, (arch, name, leaf.shape, ax)


def test_decode_cache_attn_layout():
    cfg = get_smoke_config("stablelm-3b")
    specs = SP.decode_cache_specs(cfg, DSHAPE)
    n_attn = sum(1 for k in cfg.layer_pattern if k in ("global", "local"))
    want = (cfg.n_units, n_attn, B, S, cfg.n_kv_heads, cfg.head_dim)
    assert specs["k"].shape == want
    assert specs["v"].shape == want


def test_init_decode_cache_matches_specs():
    cfg = get_smoke_config("stablelm-3b")
    live = SP.init_decode_cache(cfg, B, S)
    assert _tree_specs(live) == _tree_specs(SP.decode_cache_specs(cfg, DSHAPE))


@pytest.mark.parametrize("wta", [False, True])
def test_serve_step_shape_contract(wta):
    """(params, cache, token(B,)) -> (cache, token(B,)): the output cache
    must have exactly the input cache's specs (donation + no recompile)."""
    cfg = dataclasses.replace(get_smoke_config("stablelm-3b"), wta_head=wta)
    ps = SP.params_specs(cfg)
    cs = SP.decode_cache_specs(cfg, DSHAPE)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    step = SP.make_serve_step(cfg)
    out_cache, out_tok = jax.eval_shape(step, ps, cs, tok)
    assert _tree_specs(out_cache) == _tree_specs(cs)
    assert out_tok.shape == (B,)
    assert out_tok.dtype == jnp.int32


def test_serve_step_per_slot_key_contract():
    """Per-slot PRNG path: keys (B, 2) + step counters (B,) keep the same
    (cache, token) output contract."""
    cfg = dataclasses.replace(get_smoke_config("stablelm-3b"), wta_head=True)
    ps = SP.params_specs(cfg)
    cs = SP.decode_cache_specs(cfg, DSHAPE)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    keys = jax.ShapeDtypeStruct((B, 2), jnp.uint32)
    steps = jax.ShapeDtypeStruct((B,), jnp.int32)
    out_cache, out_tok = jax.eval_shape(
        SP.make_serve_step(cfg), ps, cs, tok, keys, steps
    )
    assert _tree_specs(out_cache) == _tree_specs(cs)
    assert out_tok.shape == (B,)


def test_cache_insert_writes_only_the_target_slot():
    cfg = get_smoke_config("stablelm-3b")
    batch_cache = SP.init_decode_cache(cfg, B, S)
    one = jax.tree.map(
        lambda l: jnp.full_like(l, 7), SP.init_decode_cache(cfg, 1, S)
    )
    insert = jax.jit(SP.make_cache_insert(cfg))
    out = insert(batch_cache, one, 2)
    assert _tree_specs(out) == _tree_specs(batch_cache)
    for name, leaf in out.items():
        ax = SP.cache_batch_axis(cfg, name)
        arr = np.moveaxis(np.asarray(leaf, np.float32), ax, 0)
        np.testing.assert_array_equal(arr[2], 7)
        np.testing.assert_array_equal(arr[[0, 1, 3]], 0)


def test_cache_insert_slot_index_is_traced():
    """One compile serves every slot index — insertion must not specialize
    on the slot value (that would recompile per refill)."""
    cfg = get_smoke_config("stablelm-3b")
    batch_cache = SP.init_decode_cache(cfg, B, S)
    one = SP.init_decode_cache(cfg, 1, S)
    insert = jax.jit(SP.make_cache_insert(cfg))
    for slot in range(B):
        insert(batch_cache, one, slot)
    ntraces = insert._cache_size()
    assert ntraces == 1, f"cache insert recompiled {ntraces}x across slots"


# ---------------------------------------------------------------------------
# Paged (block-table) cache contracts
# ---------------------------------------------------------------------------

P, BS = 9, 8  # pool pages (page 0 = trash), tokens per block


def test_paged_cache_layout():
    """Attention K/V become shared (pool, block) leaves; pos and the
    recurrent states keep the dense slot layout."""
    cfg = get_smoke_config("stablelm-3b")
    specs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    n_attn = sum(1 for k in cfg.layer_pattern if k in ("global", "local"))
    want = (cfg.n_units, n_attn, P, BS, cfg.n_kv_heads, cfg.head_dim)
    assert specs["k_pages"].shape == want
    assert specs["v_pages"].shape == want
    assert specs["pos"].shape == (B,)
    assert "k" not in specs and "v" not in specs
    live = SP.init_paged_decode_cache(cfg, B, P, BS)
    assert _tree_specs(live) == _tree_specs(specs)


def test_paged_cache_hybrid_keeps_dense_state_leaves():
    cfg = get_smoke_config("recurrentgemma-2b")
    specs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    assert specs["rec_h"].shape[2] == B  # slot axis unchanged
    assert specs["k_pages"].shape[2] == P  # pool axis, not slots


def _suffix_prefill_fixture(cfg):
    """(params, paged cache, zero state, jitted chunk fn) for one arch."""
    from repro.models import get_model_fns

    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    cache = SP.init_paged_decode_cache(cfg, B, P, BS)
    state = SP.init_prefill_state(cfg)
    fn = jax.jit(
        SP.make_paged_suffix_prefill(cfg), static_argnames=("bucket",)
    )
    return params, cache, state, fn


def test_suffix_prefill_writes_only_covered_pages():
    """A chunk's K/V land in exactly the pages its blocks cover; pages of
    other blocks (and every per-slot batch-cache leaf) stay untouched;
    the returned state carries the advanced position."""
    cfg = get_smoke_config("stablelm-3b")
    params, cache, state, fn = _suffix_prefill_fixture(cfg)
    bucket = 2 * BS
    toks = jnp.arange(1, bucket + 1, dtype=jnp.int32)[None]
    row = jnp.asarray([3, 5], jnp.int32)
    # first chunk covers block 0 only -> page 3 written, page 5 not yet
    out, st1, _ = fn(
        params, cache, state, toks[:, :BS], row,
        jnp.asarray(0, jnp.int32), bucket=bucket,
    )
    kp = np.asarray(out["k_pages"], np.float32)
    assert np.abs(kp[:, :, 3]).sum() > 0
    untouched = [p for p in range(P) if p != 3]
    np.testing.assert_array_equal(kp[:, :, untouched], 0)
    assert np.asarray(st1["pos"])[0] == BS
    # the batch cache's per-slot leaves ride along untouched: a prefill
    # in flight can never be corrupted by interleaved decode steps
    np.testing.assert_array_equal(np.asarray(out["pos"]), 0)
    # second chunk resumes at q0=BS and fills page 5
    out2, st2, logits = fn(
        params, out, st1, toks[:, BS:], row,
        jnp.asarray(BS, jnp.int32), bucket=bucket,
    )
    kp2 = np.asarray(out2["k_pages"], np.float32)
    assert np.abs(kp2[:, :, 5]).sum() > 0
    np.testing.assert_array_equal(
        kp2[:, :, [p for p in range(P) if p not in (3, 5)]], 0
    )
    assert np.asarray(st2["pos"])[0] == bucket
    assert logits.shape == (1, cfg.vocab)


def test_suffix_prefill_matches_monolithic_prefill():
    """THE equivalence anchor: one whole-bucket chunk from zeroed state
    writes bit-identical K/V to the monolithic lm_prefill and returns
    bit-identical last-token logits — which is why dense-vs-paged (and
    sharing-on-vs-off) greedy decode stays byte-identical."""
    from repro.models import transformer as TF

    for arch in ("stablelm-3b", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        params, cache, state, fn = _suffix_prefill_fixture(cfg)
        bucket = 2 * BS
        toks = (jnp.arange(bucket, dtype=jnp.int32) % 97 + 1)[None]
        row = jnp.asarray([4, 2], jnp.int32)
        out, st, logits = fn(
            params, cache, state, toks, row,
            jnp.asarray(0, jnp.int32), bucket=bucket,
        )
        ref_cache, ref_logits = TF.lm_prefill(params, toks, cfg, bucket)
        kb = np.asarray(out["k_pages"])[:, :, [4, 2]]  # (nu,na,2,BS,H,D)
        ref_k = np.asarray(ref_cache["k"])[:, :, 0].reshape(kb.shape)
        np.testing.assert_array_equal(kb, ref_k)
        vb = np.asarray(out["v_pages"])[:, :, [4, 2]]
        ref_v = np.asarray(ref_cache["v"])[:, :, 0].reshape(vb.shape)
        np.testing.assert_array_equal(vb, ref_v)
        np.testing.assert_array_equal(
            np.asarray(logits), np.asarray(ref_logits)
        )
        for name, leaf in st.items():
            if name == "pos":
                assert int(np.asarray(leaf)[0]) == bucket
            else:
                np.testing.assert_array_equal(
                    np.asarray(leaf), np.asarray(ref_cache[name])
                )


def test_suffix_prefill_start_and_pages_are_traced():
    """One compile serves every (start position, page set) of a given
    (bucket, chunk shape) — resume points and allocator page choices must
    not specialize the trace."""
    cfg = get_smoke_config("stablelm-3b")
    params, cache, state, fn = _suffix_prefill_fixture(cfg)
    bucket = 2 * BS
    toks = jnp.ones((1, BS), jnp.int32)
    for q0, row in ((0, [3, 5]), (BS, [3, 5]), (BS, [6, 1]), (0, [2, 4])):
        cache, state, _ = fn(
            params, cache, state, toks, jnp.asarray(row, jnp.int32),
            jnp.asarray(q0, jnp.int32), bucket=bucket,
        )
    ntraces = fn._cache_size()
    assert ntraces == 1, f"suffix prefill recompiled {ntraces}x"


@pytest.mark.parametrize("wta", [False, True])
def test_paged_serve_step_shape_contract(wta):
    """(params, cache, table(B,W), token(B,)) -> (cache, token, sane):
    output cache specs must equal the input's (donation + no recompile);
    sane is the per-slot int32 sanity code the engine's logit guard reads
    (0 = ok, nonzero = typed eviction reason)."""
    cfg = dataclasses.replace(get_smoke_config("stablelm-3b"), wta_head=wta)
    ps = SP.params_specs(cfg)
    cs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    tbl = jax.ShapeDtypeStruct((B, 2), jnp.int32)
    args = [ps, cs, tbl, tok]
    if wta:
        args += [
            jax.ShapeDtypeStruct((B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ]
    out_cache, out_tok, out_ok = jax.eval_shape(
        SP.make_paged_serve_step(cfg), *args
    )
    assert _tree_specs(out_cache) == _tree_specs(cs)
    assert out_tok.shape == (B,)
    assert out_tok.dtype == jnp.int32
    assert out_ok.shape == (B,)
    assert out_ok.dtype == jnp.int32


def test_paged_serve_step_rejects_encdec():
    cfg = get_smoke_config("whisper-small")
    with pytest.raises(ValueError, match="token-LM"):
        SP.make_paged_serve_step(cfg)
    with pytest.raises(ValueError, match="token-LM"):
        SP.make_paged_suffix_prefill(cfg)


def test_suffix_prefill_shape_contract():
    """(params, cache, state, tokens, row, q0) -> (cache, state, logits):
    output cache and state specs must equal the inputs' (cache donation +
    state threading across chunks rely on it)."""
    cfg = get_smoke_config("recurrentgemma-2b")
    ps = SP.params_specs(cfg)
    cs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    ss = jax.eval_shape(lambda: SP.init_prefill_state(cfg))
    out_cache, out_state, logits = jax.eval_shape(
        lambda p, c, s, t, r, q: SP.make_paged_suffix_prefill(cfg)(
            p, c, s, t, r, q, bucket=2 * BS
        ),
        ps, cs, ss,
        jax.ShapeDtypeStruct((1, BS), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    assert _tree_specs(out_cache) == _tree_specs(cs)
    assert _tree_specs(out_state) == _tree_specs(ss)
    assert logits.shape == (1, cfg.vocab)


def test_paged_cache_int8_layout():
    """int8 pools: K/V pages hold int8 codes and grow per-(page,
    slot-in-page, head) f32 scale planes; everything else keeps the bf16
    pool's layout."""
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8"
    )
    specs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    n_attn = sum(1 for k in cfg.layer_pattern if k in ("global", "local"))
    want = (cfg.n_units, n_attn, P, BS, cfg.n_kv_heads, cfg.head_dim)
    assert specs["k_pages"].shape == want
    assert specs["k_pages"].dtype == jnp.int8
    assert specs["v_pages"].dtype == jnp.int8
    assert specs["k_scale_pages"].shape == want[:-1]
    assert specs["k_scale_pages"].dtype == jnp.float32
    assert specs["v_scale_pages"].shape == want[:-1]
    live = SP.init_paged_decode_cache(cfg, B, P, BS)
    assert _tree_specs(live) == _tree_specs(specs)


@pytest.mark.parametrize("wta", [False, True])
def test_int8_paged_serve_step_shape_contract(wta):
    """The int8 pool keeps the (params, cache, table, token) -> (cache,
    token) contract with output cache specs equal to the input's — codes
    AND scale planes (donation + no recompile)."""
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8", wta_head=wta
    )
    ps = SP.params_specs(cfg)
    cs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    tbl = jax.ShapeDtypeStruct((B, 2), jnp.int32)
    args = [ps, cs, tbl, tok]
    if wta:
        args += [
            jax.ShapeDtypeStruct((B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ]
    out_cache, out_tok, out_ok = jax.eval_shape(
        SP.make_paged_serve_step(cfg), *args
    )
    assert _tree_specs(out_cache) == _tree_specs(cs)
    assert out_tok.shape == (B,)
    assert out_ok.shape == (B,) and out_ok.dtype == jnp.int32


def test_page_spill_restore_shape_contract():
    """spill: (cache, ids(W,)) -> {pool leaf: rows}; restore scatters the
    payload back and must return cache specs equal to the input's
    (donation); gather: (cache, slot) -> the exact init_prefill_state
    pytree, so state_insert reuses its one compile on restore."""
    cfg = get_smoke_config("recurrentgemma-2b")
    cs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    ids = jax.ShapeDtypeStruct((3,), jnp.int32)
    payload = jax.eval_shape(SP.make_page_spill(cfg), cs, ids)
    assert set(payload) == {k for k in SP.PAGE_POOL_LEAVES if k in cs}
    for name, rows in payload.items():
        want = list(cs[name].shape)
        want[2] = 3
        assert rows.shape == tuple(want), (name, rows.shape)
    out = jax.eval_shape(SP.make_page_restore(cfg), cs, ids, payload)
    assert _tree_specs(out) == _tree_specs(cs)
    state = jax.eval_shape(
        SP.make_slot_state_gather(cfg), cs, jax.ShapeDtypeStruct((), jnp.int32)
    )
    ref = jax.eval_shape(lambda: SP.init_prefill_state(cfg))
    assert _tree_specs(state) == _tree_specs(ref)


def test_int8_spill_payload_excludes_global_quant_step():
    """The int8 pool's stochastic-rounding step counter is a GLOBAL
    scalar, not per-slot state — the slot gather must skip it (restoring
    it would replay other slots' rounding draws)."""
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8"
    )
    cs = SP.paged_decode_cache_specs(cfg, B, P, BS)
    assert "quant_step" in cs
    state = jax.eval_shape(
        SP.make_slot_state_gather(cfg), cs, jax.ShapeDtypeStruct((), jnp.int32)
    )
    assert "quant_step" not in state
    # but the scale planes DO spill with the pages
    payload = jax.eval_shape(
        SP.make_page_spill(cfg), cs, jax.ShapeDtypeStruct((2,), jnp.int32)
    )
    assert "k_scale_pages" in payload and "v_scale_pages" in payload


def test_int8_suffix_prefill_quantizes_into_covered_pages():
    """A chunk lands as int8 codes + scales in exactly the pages it
    covers; untouched pages keep zero codes and unit scales; the first
    unit's dequantized codes reconstruct the full-precision K the
    monolithic prefill computes within one scale step (the
    stochastic-rounding error bound).  Only unit 0 is compared: the
    chunked int8 prefill attends against the already-quantized pages, so
    deeper units' K legitimately absorb upstream quantization error —
    exactly what their decode-time readers see (engine-level agreement is
    pinned at token level by tests/test_serving.py)."""
    from repro.models import transformer as TF

    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8"
    )
    fp_cfg = dataclasses.replace(cfg, kv_cache_dtype="same")
    params, cache, state, fn = _suffix_prefill_fixture(cfg)
    bucket = 2 * BS
    toks = (jnp.arange(bucket, dtype=jnp.int32) % 89 + 1)[None]
    row = jnp.asarray([3, 5], jnp.int32)
    seeds = jnp.asarray([7, 9], jnp.uint32)  # per-block content seeds
    out, st, _ = fn(
        params, cache, state, toks, row,
        jnp.asarray(0, jnp.int32), seeds, bucket=bucket,
    )
    kp = np.asarray(out["k_pages"], np.float32)
    ks = np.asarray(out["k_scale_pages"], np.float32)
    untouched = [p for p in range(P) if p not in (3, 5)]
    np.testing.assert_array_equal(kp[:, :, untouched], 0)
    np.testing.assert_array_equal(ks[:, :, untouched], 1.0)
    ref_cache, _ = TF.lm_prefill(params, toks, fp_cfg, bucket)
    nu, na, _, L, hkv, dh = ref_cache["k"].shape
    src = np.asarray(ref_cache["k"], np.float32)[:, :, 0].reshape(
        nu, na, 2, BS, hkv, dh
    )
    deq = kp[:, :, [3, 5]] * ks[:, :, [3, 5], ..., None] / 127.0
    step = ks[:, :, [3, 5], ..., None] / 127.0
    assert np.all(np.abs(deq - src)[0] <= step[0] + 1e-6)
    # the scale plane is the per-row max |K| of the same unit-0 source
    sc_ref = np.maximum(np.abs(src).max(-1), 1e-6)
    np.testing.assert_allclose(
        ks[:, :, [3, 5]][0], sc_ref[0], rtol=1e-6
    )
    assert np.asarray(st["pos"])[0] == bucket


def test_int8_suffix_prefill_seeds_are_content_positional():
    """The prefix-sharing contract on the quantizer: a block's codes are
    a function of (block content at position, block seed, layer) ONLY —
    not of what the rest of the prompt is.  Two prefills agreeing on
    block 0 (same tokens, same seed) write bit-identical codes for it
    even though their second blocks differ; the same seed on different
    content must not."""
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8"
    )
    params, cache, state, fn = _suffix_prefill_fixture(cfg)
    bucket = 2 * BS
    toks_a = jnp.concatenate(
        [jnp.arange(1, BS + 1), jnp.arange(30, 30 + BS)]
    ).astype(jnp.int32)[None]
    toks_b = jnp.concatenate(
        [jnp.arange(1, BS + 1), jnp.arange(60, 60 + BS)]
    ).astype(jnp.int32)[None]
    seeds = jnp.asarray([7, 9], jnp.uint32)
    out_a, _, _ = fn(
        params, cache, state, toks_a, jnp.asarray([1, 2], jnp.int32),
        jnp.asarray(0, jnp.int32), seeds, bucket=bucket,
    )
    out_b, _, _ = fn(
        params, cache, state, toks_b, jnp.asarray([3, 4], jnp.int32),
        jnp.asarray(0, jnp.int32), seeds, bucket=bucket,
    )
    np.testing.assert_array_equal(
        np.asarray(out_a["k_pages"])[:, :, 1],
        np.asarray(out_b["k_pages"])[:, :, 3],
    )
    np.testing.assert_array_equal(
        np.asarray(out_a["v_pages"])[:, :, 1],
        np.asarray(out_b["v_pages"])[:, :, 3],
    )
    # same seed, different content → different codes (sanity)
    assert not np.array_equal(
        np.asarray(out_a["k_pages"])[:, :, 2],
        np.asarray(out_b["k_pages"])[:, :, 4],
    )


def test_int8_suffix_prefill_seeds_are_traced():
    """One compile serves every (page set, start, per-block seed vector)
    — the stochastic-rounding seeds must not trigger per-request
    recompiles."""
    cfg = dataclasses.replace(
        get_smoke_config("stablelm-3b"), kv_cache_dtype="int8"
    )
    params, cache, state, fn = _suffix_prefill_fixture(cfg)
    for i in range(3):
        cache, state, _ = fn(
            params, cache, state, jnp.ones((1, BS), jnp.int32),
            jnp.asarray([i + 1], jnp.int32), jnp.asarray(0, jnp.int32),
            jnp.asarray([i * 13 + 1], jnp.uint32), bucket=BS,
        )
    ntraces = fn._cache_size()
    assert ntraces == 1, f"int8 suffix prefill recompiled {ntraces}x"


# ---------------------------------------------------------------------------
# Prefix-sharing entry points (state insert + COW page copy)
# ---------------------------------------------------------------------------


def test_paged_state_insert_writes_only_dense_leaves_at_slot():
    """The full-hit admission path: per-slot leaves (pos, recurrent
    states) land at the slot, the shared page pools are untouched."""
    cfg = get_smoke_config("recurrentgemma-2b")
    cache = SP.init_paged_decode_cache(cfg, B, P, BS)
    one = SP.init_decode_cache(cfg, 1, BS)
    state = {
        n: jnp.full_like(v, 7)
        for n, v in one.items() if n not in ("k", "v")
    }
    insert = jax.jit(SP.make_paged_state_insert(cfg))
    out = insert(cache, state, 2)
    for name in state:
        ax = SP.cache_batch_axis(cfg, name)
        arr = np.moveaxis(np.asarray(out[name], np.float32), ax, 0)
        np.testing.assert_array_equal(arr[2], 7)
        np.testing.assert_array_equal(arr[[0, 1, 3]], 0)
    np.testing.assert_array_equal(np.asarray(out["k_pages"]), 0)
    np.testing.assert_array_equal(np.asarray(out["v_pages"]), 0)


def test_paged_state_insert_slot_is_traced():
    cfg = get_smoke_config("stablelm-3b")
    cache = SP.init_paged_decode_cache(cfg, B, P, BS)
    one = SP.init_decode_cache(cfg, 1, BS)
    state = {n: v for n, v in one.items() if n not in ("k", "v")}
    insert = jax.jit(SP.make_paged_state_insert(cfg))
    for slot in range(B):
        insert(cache, state, slot)
    ntraces = insert._cache_size()
    assert ntraces == 1, f"state insert recompiled {ntraces}x"


@pytest.mark.parametrize("int8", [False, True])
def test_page_copy_copies_every_pool_leaf(int8):
    """The device half of a COW fork: page dst becomes a bit-copy of page
    src on every pool leaf (codes AND scale planes for int8), and no other
    page or per-slot leaf moves."""
    cfg = get_smoke_config("stablelm-3b")
    if int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    cache = SP.init_paged_decode_cache(cfg, B, P, BS)
    pool_names = [n for n in cache if n.endswith("_pages")]
    for i, n in enumerate(pool_names):
        fill = jnp.arange(cache[n].size, dtype=jnp.float32).reshape(
            cache[n].shape
        ) % 113 + i
        cache[n] = fill.astype(cache[n].dtype)
    before = {n: np.asarray(cache[n]) for n in cache}
    copy = jax.jit(SP.make_page_copy(cfg))
    out = copy(cache, 3, 5)
    for n in pool_names:
        arr = np.asarray(out[n])
        np.testing.assert_array_equal(arr[:, :, 5], before[n][:, :, 3])
        others = [p for p in range(P) if p != 5]
        np.testing.assert_array_equal(arr[:, :, others], before[n][:, :, others])
    for n in cache:
        if n not in pool_names:
            np.testing.assert_array_equal(np.asarray(out[n]), before[n])


def test_page_copy_page_ids_are_traced():
    cfg = get_smoke_config("stablelm-3b")
    cache = SP.init_paged_decode_cache(cfg, B, P, BS)
    copy = jax.jit(SP.make_page_copy(cfg))
    for src, dst in ((1, 2), (3, 4), (5, 1)):
        cache = copy(cache, src, dst)
    ntraces = copy._cache_size()
    assert ntraces == 1, f"page copy recompiled {ntraces}x"


def test_sample_tokens_greedy_and_legacy_key():
    cfg = get_smoke_config("stablelm-3b")
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.vocab))
    toks = SP.sample_tokens(cfg, logits)  # no key -> argmax
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1))
    )
    # wta off: a provided key must be ignored
    toks2 = SP.sample_tokens(cfg, logits, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))
    # legacy single-key WTA path still returns (B,) int32
    wcfg = dataclasses.replace(cfg, wta_head=True)
    toks3 = SP.sample_tokens(wcfg, logits, jax.random.PRNGKey(1))
    assert toks3.shape == (B,)
    assert toks3.dtype == jnp.int32


# ---------------------------------------------------------------------------
# Speculative round / rollback (draft-k + expanded-batch verify)
# ---------------------------------------------------------------------------

SPEC_K = 3


def _spec_fixture(arch="stablelm-3b"):
    """A B=3 paged cache with slot 0 prefilled (pages [1, 2], 8-token
    prompt) and slots 1-2 parked on the trash page."""
    from repro.models import get_model_fns

    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    nb, ps = 3, 8
    cache = SP.init_paged_decode_cache(cfg, nb, ps, BS)
    prefill = jax.jit(
        SP.make_paged_suffix_prefill(cfg), static_argnames=("bucket",)
    )
    prompt = [5, 3, 7, 2, 9, 4, 6, 8]
    cache, st, _ = prefill(
        params, cache, SP.init_prefill_state(cfg),
        jnp.asarray([prompt], jnp.int32),
        jnp.asarray([1], jnp.int32), jnp.int32(0), bucket=8,
    )
    cache = jax.jit(SP.make_paged_state_insert(cfg))(cache, st, jnp.int32(0))
    table = jnp.asarray([[1, 2], [0, 0], [0, 0]], jnp.int32)
    token = jnp.asarray([7, 0, 0], jnp.int32)
    keys = jnp.zeros((nb, 2), jnp.uint32)
    steps = jnp.zeros((nb,), jnp.int32)
    return cfg, params, cache, table, token, keys, steps


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_spec_round_matches_plain_chain(arch):
    """Contract of the fused round: drafts are bitwise the k chained
    plain decode steps, the greedy verify resamples the drafts exactly
    (fault-free rounds accept everything), vstates carries the per-step
    states, and the returned cache equals the plain chain's end state."""
    from repro.models import transformer as TF

    cfg, params, cache, table, token, keys, steps = _spec_fixture(arch)
    rnd = jax.jit(SP.make_paged_spec_round(cfg, SPEC_K))
    out_cache, d, dok, v, vok, vs = rnd(
        params, cache, table, token, keys, steps
    )
    assert d.shape == v.shape == dok.shape == vok.shape == (3, SPEC_K)
    for leaf in vs.values():
        assert leaf.shape[0] == SPEC_K
    assert np.asarray(dok).all() and np.asarray(vok).all()
    np.testing.assert_array_equal(np.asarray(v), np.asarray(d))

    step = jax.jit(
        lambda p, c, t: TF.lm_decode_step(p, c, t, cfg, table)
    )
    c, t = cache, token
    for j in range(SPEC_K):
        c, logits = step(params, c, t)
        t = SP.sample_tokens(cfg, logits, keys, steps + j)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(d[:, j]))
        # vstates[j] = the state AFTER consuming input j — bitwise the
        # plain chain's state (pos included)
        np.testing.assert_array_equal(
            np.asarray(vs["pos"][j]), np.asarray(c["pos"])
        )
    for name in c:
        np.testing.assert_array_equal(
            np.asarray(out_cache[name]), np.asarray(c[name]), err_msg=name
        )


def test_spec_rollback_rewinds_one_slot():
    cfg, params, cache, table, token, keys, steps = _spec_fixture()
    pre_pos = np.asarray(cache["pos"]).copy()
    rnd = jax.jit(SP.make_paged_spec_round(cfg, SPEC_K))
    out_cache, *_, vs = rnd(params, cache, table, token, keys, steps)
    rb = jax.jit(SP.make_spec_rollback(cfg))
    back = rb(out_cache, vs, jnp.int32(1), jnp.int32(0))
    pos = np.asarray(back["pos"])
    assert pos[0] == pre_pos[0] + 2  # idx 1 = consumed inputs 0 and 1
    np.testing.assert_array_equal(pos[1:], np.asarray(out_cache["pos"])[1:])
    # idx and slot are traced: every (idx, slot) pair reuses one trace
    back = rb(back, vs, jnp.int32(0), jnp.int32(2))
    assert rb._cache_size() == 1


def test_decode_step_kv_write_false_is_read_only():
    """The verify cell: run the writing step once (the 'draft' — it lands
    the token's K/V row in the pool), then re-decode the same position
    read-only from the written pool + the pre-step dense state.  Logits
    must match bitwise and the returned cache must carry only dense
    per-slot leaves (no pool pages, no quant_step tick)."""
    from repro.models import transformer as TF

    cfg, params, cache, table, token, keys, steps = _spec_fixture()
    wr = jax.jit(lambda p, c, t: TF.lm_decode_step(p, c, t, cfg, table))
    ro = jax.jit(
        lambda p, c, t: TF.lm_decode_step(
            p, c, t, cfg, table, kv_write=False
        )
    )
    c_wr, lg_wr = wr(params, cache, token)
    # written pool + pre-step dense state = a verify row for this position
    replay = dict(c_wr)
    for name in SP._spec_state_leaves(cache):
        replay[name] = cache[name]
    c_ro, lg_ro = ro(params, replay, token)
    np.testing.assert_array_equal(np.asarray(lg_wr), np.asarray(lg_ro))
    pool = set(SP.PAGE_POOL_LEAVES) | {"quant_step"}
    assert set(c_ro) == set(replay) - (pool & set(replay))
    np.testing.assert_array_equal(
        np.asarray(c_ro["pos"]), np.asarray(c_wr["pos"])
    )


def test_spec_factories_reject_bad_args():
    cfg = get_smoke_config("stablelm-3b")
    with pytest.raises(ValueError, match="speculate_k"):
        SP.make_paged_spec_round(cfg, 0)
    encdec = get_smoke_config("whisper-small")
    with pytest.raises(ValueError, match="token-LM"):
        SP.make_paged_spec_round(encdec, 2)
    with pytest.raises(ValueError, match="token-LM"):
        SP.make_spec_rollback(encdec)
