"""Per-kernel validation: Pallas (TPU-interpret) vs pure-jnp oracles.

The kernels share a counter-based PRNG with the oracles, so stochastic
paths are compared bit-exactly (binary agreement / identical levels), and
deterministic paths with f32-matmul tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core.analog import AnalogConfig
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.kernels import ops, prng

CFG = AnalogConfig(
    mode="analog_stochastic", device=calibrate_v_read(DeviceParams(), 512)
)
KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# crossbar_mac
# ---------------------------------------------------------------------------

SHAPES = [
    (8, 64, 16),       # tiny, all dims sub-block
    (100, 300, 200),   # unaligned
    (128, 512, 128),   # exactly one block
    (64, 1200, 130),   # multi-K-block accumulation
    (257, 513, 129),   # off-by-one on every dim
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossbar_linear_matches_oracle(m, k, n, dtype):
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32) * 0.05
    y_k = ops.crossbar_mac(x, w, KEY, CFG, binarize=False)
    y_r = ops.crossbar_mac_reference(x, w, KEY, CFG, binarize=False)
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_r), atol=2e-5, rtol=1e-5
    )


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_crossbar_binary_agreement(m, k, n):
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    y_k = ops.crossbar_mac(x, w, KEY, CFG, binarize=True)
    y_r = ops.crossbar_mac_reference(x, w, KEY, CFG, binarize=True)
    assert set(np.unique(np.asarray(y_k))) <= {0.0, 1.0}
    # identical PRNG; only f32 matmul reassociation at threshold can differ
    agreement = float((y_k == y_r).mean())
    assert agreement > 0.9995, agreement


def test_crossbar_physical_noise_path():
    cfgp = AnalogConfig(
        mode="analog_stochastic", device=CFG.device, calibrated=False
    )
    x = jax.random.normal(KEY, (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(2), (512, 128)) * 0.05
    y_k = ops.crossbar_mac(x, w, KEY, cfgp, binarize=True)
    y_r = ops.crossbar_mac_reference(x, w, KEY, cfgp, binarize=True)
    assert float((y_k == y_r).mean()) > 0.9995


def test_crossbar_batched_leading_dims():
    x = jax.random.normal(KEY, (4, 6, 96))
    w = jax.random.normal(jax.random.PRNGKey(3), (96, 32)) * 0.1
    y = ops.crossbar_mac(x, w, KEY, CFG, binarize=False)
    assert y.shape == (4, 6, 32)


def test_crossbar_gradients_match_ste_surrogate():
    """Backward of the kernel == analytic STE formula."""
    x = jax.random.normal(KEY, (32, 128))
    w = jax.random.normal(jax.random.PRNGKey(4), (128, 64)) * 0.1

    g_w = jax.grad(
        lambda w: jnp.sum(ops.crossbar_mac(x, w, KEY, CFG, True) ** 2)
    )(w)
    assert bool(jnp.all(jnp.isfinite(g_w)))
    # compare direction with the dense surrogate E[y]=sigmoid(z)
    from repro.core import analog as A

    wq = A.quantize_normalized(w, CFG)
    y_hard = ops.crossbar_mac(x, w, KEY, CFG, True)

    def surrogate(w2):
        # identity-STE through the quantizer (jnp.round has zero grad)
        wq2 = w2 + jax.lax.stop_gradient(wq - w2)
        p = jax.nn.sigmoid(x @ wq2)
        return jnp.sum(
            y_hard**2 + 2 * y_hard * (p - jax.lax.stop_gradient(p))
        )

    # d/dw of sum(y^2) under STE: 2·y·dp/dw
    g_ref = jax.grad(surrogate)(w)
    np.testing.assert_allclose(
        np.asarray(g_w), np.asarray(g_ref), atol=3e-5, rtol=1e-3
    )


def test_noise_statistics_linear_mode():
    """Linear (high-SNR) readout: residual noise std == s·linear_sigma."""
    x = jnp.zeros((256, 512))
    w = jax.random.normal(jax.random.PRNGKey(9), (512, 256)) * 0.05
    cfg_nq = AnalogConfig(
        mode="analog_stochastic", device=CFG.device, quantize=False
    )
    y = ops.crossbar_mac(x, w, KEY, cfg_nq, binarize=False)
    # x = 0 => output is pure noise: std = s·linear_sigma
    s_expect = float(jnp.max(jnp.abs(w))) * cfg_nq.linear_sigma
    assert abs(float(jnp.std(y)) - s_expect) / s_expect < 0.05
    assert abs(float(jnp.mean(y))) < s_expect * 0.05


def test_fire_rate_half_at_zero_drive():
    """Comparator at z=0 fires with probability 1/2 (calibration anchor)."""
    x = jnp.zeros((128, 256))
    w = jax.random.normal(jax.random.PRNGKey(10), (256, 128)) * 0.05
    y = ops.crossbar_mac(x, w, KEY, CFG, binarize=True)
    assert abs(float(y.mean()) - 0.5) < 0.02


# ---------------------------------------------------------------------------
# wta kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,c", [(1, 10), (7, 10), (130, 5), (16, 200)])
def test_wta_kernel_bit_exact(b, c):
    z = jax.random.normal(jax.random.PRNGKey(5), (b, c))
    kw = dict(n_trials=64, vth0=2.897, sigma_z=1.702)
    ck = ops.wta_counts(z, KEY, **kw)
    cr = ops.wta_counts_reference(z, KEY, **kw)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))


def test_wta_kernel_matches_core_distribution():
    """Kernel votes converge to the same softmax the core simulator gives."""
    from repro.core import wta as W

    z = jnp.asarray([[1.0, 0.0, -1.0, 0.5, 2.0, -0.5, 0.2, -1.5]])
    theta = W.calibrated_threshold()
    counts = ops.wta_counts(z, KEY, n_trials=20_000, vth0=theta, sigma_z=1.702)
    probs = counts / counts.sum()
    sm = jax.nn.softmax(z)
    assert 0.5 * float(jnp.abs(probs - sm).sum()) < 0.08


# ---------------------------------------------------------------------------
# stoch_round kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(33, 70), (256, 512), (5, 1030)])
def test_stoch_round_levels_match_oracle(shape):
    x = jax.random.normal(jax.random.PRNGKey(6), shape)
    step = 2.0 / 31
    qk = ops.stoch_round(x, KEY, step=step, lo=-1, hi=1)
    qr = ops.stoch_round_reference(x, KEY, step=step, lo=-1, hi=1)
    np.testing.assert_allclose(
        np.asarray(qk), np.asarray(qr), atol=step * 1e-3
    )
    lv = (np.asarray(qk) + 1) / step
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-3)


@hypothesis.given(
    step=st.sampled_from([2 / 31, 2 / 15, 0.1]),
    seed=st.integers(0, 10_000),
)
@hypothesis.settings(deadline=None, max_examples=15)
def test_stoch_round_unbiased(step, seed):
    """E[q(x)] == clip(x) — the conductance-programming invariant."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 16)) * 0.8
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), 300)
    qs = jnp.stack(
        [
            ops.stoch_round_reference(x, k2, step=step, lo=-1, hi=1)
            for k2 in keys
        ]
    ).mean(0)
    err = np.abs(np.asarray(qs) - np.clip(np.asarray(x), -1, 1)).max()
    assert err < step * 0.35, err


def test_stoch_round_ste_gradient():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.7, 1.5])
    g = jax.grad(
        lambda v: jnp.sum(ops.stoch_round(v[None], KEY, step=0.1, lo=-1, hi=1))
    )(x)
    np.testing.assert_allclose(np.asarray(g), [0, 1, 1, 1, 0], atol=1e-6)


# ---------------------------------------------------------------------------
# paged attention (serving decode kernel)
# ---------------------------------------------------------------------------


def _paged_case(seed, b, h, hkv, dh, n_pages, bs, w):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, bs, hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, bs, hkv, dh), jnp.float32)
    # distinct pages per slot (page 0 = trash, never tabled)
    perm = jax.random.permutation(ks[3], n_pages - 1)[: b * w] + 1
    table = perm.reshape(b, w).astype(jnp.int32)
    return q, kp, vp, table


@pytest.mark.parametrize("kind,local_window", [("global", 0), ("local", 5)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_attention_kernel_matches_oracle(kind, local_window, softcap):
    """Interpret-mode kernel vs the pure-jnp gather oracle: GQA heads,
    positions mid-block, both mask kinds, with/without soft-capping."""
    from repro.kernels.paged_attention import paged_attention_pallas

    b, w, bs = 4, 3, 8
    q, kp, vp, table = _paged_case(0, b, 4, 2, 16, 16, bs, w)
    # pos exercises: block-boundary, mid-block, first token, full window
    pos = jnp.asarray([15, 12, 0, 23], jnp.int32)
    y_ref = ops.ref.paged_attention_ref(
        q, kp, vp, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
    )
    y_k = paged_attention_pallas(
        q, kp, vp, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), atol=2e-5, rtol=1e-5
    )


def test_paged_attention_ignores_blocks_beyond_pos():
    """Pages past a slot's position must not leak into the output: poison
    them with huge values and check against a short-table oracle."""
    from repro.kernels.paged_attention import paged_attention_pallas

    b, w, bs = 2, 4, 8
    q, kp, vp, table = _paged_case(1, b, 4, 4, 16, 12, bs, w)
    pos = jnp.asarray([7, 3], jnp.int32)  # only block 0 is valid
    poison = np.asarray(table[:, 1:]).ravel()
    kp = kp.at[poison].set(1e9)
    vp = vp.at[poison].set(1e9)
    y_short = ops.ref.paged_attention_ref(
        q, kp, vp, table[:, :1], pos, kind="global"
    )
    y_k = paged_attention_pallas(
        q, kp, vp, table, pos, kind="global", interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_short), atol=2e-5, rtol=1e-5
    )


def test_paged_attention_op_dispatches_off_tpu():
    """ops.paged_attention falls back to the oracle off-TPU (the serving
    hot loop must not run interpret-mode emulation)."""
    q, kp, vp, table = _paged_case(2, 2, 4, 2, 16, 8, 8, 2)
    pos = jnp.asarray([9, 4], jnp.int32)
    y = ops.paged_attention(q, kp, vp, table, pos)
    y_ref = ops.ref.paged_attention_ref(q, kp, vp, table, pos)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# prefix-aware chunked-prefill attention (suffix-only prefill kernel)
# ---------------------------------------------------------------------------


def _suffix_case(seed, s, h, hkv, dh, n_pages, bs, w):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (s, h, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, bs, hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, bs, hkv, dh), jnp.float32)
    perm = jax.random.permutation(ks[3], n_pages - 1)[:w] + 1
    table = perm.astype(jnp.int32)
    return q, kp, vp, table


@pytest.mark.parametrize("kind,local_window", [("global", 0), ("local", 5)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize(
    "s,q0",
    [
        (8, 0),    # whole-prompt chunk from position 0
        (8, 16),   # suffix chunk starting exactly at a block boundary
        (5, 16),   # ragged suffix length, block-boundary start
        (13, 8),   # ragged length spanning several blocks
    ],
)
def test_prefill_attention_kernel_matches_oracle(
    kind, local_window, softcap, s, q0
):
    """Interpret-mode kernel vs the pure-jnp oracle: GQA heads, ragged
    suffix lengths, block-boundary suffix starts, both mask kinds, with
    and without soft-capping."""
    from repro.kernels.prefill_attention import paged_prefill_attention_pallas

    bs, w = 8, 4
    q, kp, vp, table = _suffix_case(0, s, 4, 2, 16, 16, bs, w)
    y_ref = ops.ref.prefill_attention_ref(
        q, kp, vp, table, jnp.asarray(q0, jnp.int32),
        kind=kind, local_window=local_window, softcap=softcap,
    )
    y_k = paged_prefill_attention_pallas(
        q, kp, vp, table, jnp.asarray(q0, jnp.int32),
        kind=kind, local_window=local_window, softcap=softcap,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), atol=2e-5, rtol=1e-5
    )


def test_prefill_attention_kernel_matches_oracle_int8():
    """int8 pages + scale planes: the fused-dequant kernel path agrees
    with the oracle's scores-not-cache math on a mid-prompt suffix."""
    from repro.kernels.prefill_attention import paged_prefill_attention_pallas

    s, bs, w = 7, 8, 3
    q, kp, vp, table = _suffix_case(3, s, 4, 2, 16, 12, bs, w)
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    kp8 = jax.random.randint(ks[0], kp.shape, -127, 128, jnp.int32).astype(
        jnp.int8
    )
    vp8 = jax.random.randint(ks[1], vp.shape, -127, 128, jnp.int32).astype(
        jnp.int8
    )
    k_scale = jnp.abs(
        jax.random.normal(ks[0], kp.shape[:3], jnp.float32)
    ) + 0.1
    v_scale = jnp.abs(
        jax.random.normal(ks[1], vp.shape[:3], jnp.float32)
    ) + 0.1
    q0 = jnp.asarray(8, jnp.int32)
    y_ref = ops.ref.prefill_attention_ref(
        q, kp8, vp8, table, q0, k_scale=k_scale, v_scale=v_scale
    )
    y_k = paged_prefill_attention_pallas(
        q, kp8, vp8, table, q0, k_scale=k_scale, v_scale=v_scale,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), atol=2e-5, rtol=1e-5
    )


def test_prefill_attention_suffix_matches_full_restriction():
    """Per-query independence — the property suffix-only prefill rests
    on: computing only the suffix's queries must give exactly the same
    rows the full-prompt oracle gives for those positions."""
    bs, w = 8, 3
    q, kp, vp, table = _suffix_case(5, bs * w, 4, 4, 16, 12, bs, w)
    y_full = ops.ref.prefill_attention_ref(
        q, kp, vp, table, jnp.asarray(0, jnp.int32)
    )
    suffix = q[16:]
    y_sfx = ops.ref.prefill_attention_ref(
        suffix, kp, vp, table, jnp.asarray(16, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(y_sfx), np.asarray(y_full)[16:], atol=1e-6, rtol=1e-6
    )


def test_prefill_attention_ignores_blocks_beyond_chunk():
    """Pages entirely beyond the chunk's last query must not leak into
    the output: poison them and compare against a short-table oracle."""
    from repro.kernels.prefill_attention import paged_prefill_attention_pallas

    s, bs, w = 6, 8, 4
    q, kp, vp, table = _suffix_case(7, s, 4, 4, 16, 12, bs, w)
    q0 = jnp.asarray(8, jnp.int32)  # queries cover positions 8..13
    poison = np.asarray(table[2:])
    kp = kp.at[poison].set(1e9)
    vp = vp.at[poison].set(1e9)
    y_short = ops.ref.prefill_attention_ref(q, kp, vp, table[:2], q0)
    y_k = paged_prefill_attention_pallas(
        q, kp, vp, table, q0, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_short), atol=2e-5, rtol=1e-5
    )


def test_prefill_attention_op_dispatches_off_tpu():
    """ops.paged_prefill_attention falls back to the oracle off-TPU (the
    serving prefill path must not run interpret-mode emulation)."""
    q, kp, vp, table = _suffix_case(2, 5, 4, 2, 16, 8, 8, 2)
    q0 = jnp.asarray(8, jnp.int32)
    y = ops.paged_prefill_attention(q, kp, vp, table, q0)
    y_ref = ops.ref.prefill_attention_ref(q, kp, vp, table, q0)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# portable PRNG quality
# ---------------------------------------------------------------------------


def test_prng_gaussian_moments():
    idx = jnp.arange(200_000, dtype=jnp.uint32)
    g = prng.gaussian(idx, jnp.uint32(7))
    assert abs(float(g.mean())) < 0.01
    assert abs(float(g.std()) - 1.0) < 0.01
    kurt = float(((g - g.mean()) ** 4).mean() / g.std() ** 4)
    assert abs(kurt - 3.0) < 0.1


def test_prng_streams_decorrelated():
    idx = jnp.arange(100_000, dtype=jnp.uint32)
    a = prng.gaussian(idx, jnp.uint32(1))
    b = prng.gaussian(idx, jnp.uint32(2))
    corr = float(jnp.corrcoef(a, b)[0, 1])
    assert abs(corr) < 0.02
    # sequential correlation within one stream
    corr2 = float(jnp.corrcoef(a[:-1], a[1:])[0, 1])
    assert abs(corr2) < 0.02


# ---------------------------------------------------------------------------
# int8 paged attention (fused-dequant serving decode kernel)
# ---------------------------------------------------------------------------


def _int8_paged_case(seed, b, h, hkv, dh, n_pages, bs, w):
    q, kp, vp, table = _paged_case(seed, b, h, hkv, dh, n_pages, bs, w)
    k8, ks = ops.quantize_kv_int8(kp, jnp.uint32(seed))
    v8, vs = ops.quantize_kv_int8(vp, jnp.uint32(seed + 77))
    return q, kp, vp, k8, v8, ks, vs, table


@pytest.mark.parametrize("kind,local_window", [("global", 0), ("local", 5)])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_int8_paged_attention_kernel_matches_oracle(
    kind, local_window, softcap
):
    """Interpret-mode fused-dequant kernel vs the int8 oracle: int8 codes +
    scale planes in, scales applied to scores/weights in VMEM."""
    from repro.kernels.paged_attention import paged_attention_pallas

    b, w, bs = 4, 3, 8
    q, _, _, k8, v8, ks, vs, table = _int8_paged_case(
        3, b, 4, 2, 16, 16, bs, w
    )
    pos = jnp.asarray([15, 12, 0, 23], jnp.int32)
    y_ref = ops.ref.paged_attention_ref(
        q, k8, v8, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=ks, v_scale=vs,
    )
    y_k = paged_attention_pallas(
        q, k8, v8, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=ks, v_scale=vs, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_ref), atol=2e-5, rtol=2e-5
    )


def test_int8_paged_attention_close_to_full_precision():
    """The quantized path is an approximation of the f32 pool with bounded
    error: per-row max-abs scales keep the relative readout error small."""
    q, kp, vp, k8, v8, ks, vs, table = _int8_paged_case(
        4, 2, 4, 2, 16, 8, 8, 2
    )
    pos = jnp.asarray([9, 4], jnp.int32)
    y_fp = ops.ref.paged_attention_ref(q, kp, vp, table, pos)
    y_i8 = ops.ref.paged_attention_ref(
        q, k8, v8, table, pos, k_scale=ks, v_scale=vs
    )
    rel = float(
        jnp.max(jnp.abs(y_i8 - y_fp)) / jnp.max(jnp.abs(y_fp))
    )
    assert rel < 0.05, rel


def test_int8_paged_attention_op_dispatches_off_tpu():
    q, _, _, k8, v8, ks, vs, table = _int8_paged_case(5, 2, 4, 2, 16, 8, 8, 2)
    pos = jnp.asarray([9, 4], jnp.int32)
    y = ops.paged_attention(q, k8, v8, table, pos, k_scale=ks, v_scale=vs)
    y_ref = ops.ref.paged_attention_ref(
        q, k8, v8, table, pos, k_scale=ks, v_scale=vs
    )
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# quantize_kv_int8 (stochastic-rounded cache quantizer)
# ---------------------------------------------------------------------------


def test_quantize_kv_int8_error_bounded_by_scale_step():
    """Stochastic rounding moves each element to an adjacent grid level:
    |dequant - x| <= scale/127 elementwise, codes within [-127, 127]."""
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 3, 32), jnp.float32)
    codes, scale = ops.quantize_kv_int8(x, jnp.uint32(11))
    assert codes.dtype == jnp.int8
    assert scale.shape == x.shape[:-1]
    step = scale[..., None] / 127.0
    deq = codes.astype(jnp.float32) * step
    assert bool(jnp.all(jnp.abs(deq - x) <= step + 1e-6))
    assert int(jnp.max(jnp.abs(codes.astype(jnp.int32)))) <= 127


def test_quantize_kv_int8_unbiased_over_seeds():
    """E[dequant] ~= x over stochastic-rounding seeds — the paper's
    unbiased conductance-programming property on the cache path.  With 256
    seeds the worst-case element bias stays well inside the ~4-sigma band
    of an unbiased rounder (sigma <= 0.5 step / sqrt(256))."""
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64), jnp.float32)
    _, scale = ops.quantize_kv_int8(x, jnp.uint32(0))
    step = scale[..., None] / 127.0
    acc = jnp.zeros_like(x)
    n = 256
    for s in range(n):
        codes, _ = ops.quantize_kv_int8(x, jnp.uint32(s))
        acc = acc + codes.astype(jnp.float32) * step
    bias_steps = jnp.max(jnp.abs(acc / n - x) / step)
    assert float(bias_steps) < 0.2, float(bias_steps)


def test_quantize_kv_int8_seed_varies_rounding():
    """Different seeds must draw different rounding decisions (the decode
    step feeds a fresh per-(step, layer) seed so cache noise never
    repeats)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 32), jnp.float32)
    c0, _ = ops.quantize_kv_int8(x, jnp.uint32(0))
    c1, _ = ops.quantize_kv_int8(x, jnp.uint32(1))
    assert bool(jnp.any(c0 != c1))
