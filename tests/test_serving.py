"""Continuous-batching serving engine: scheduler lifecycle, block
allocator + paged-cache behavior (back-pressure, reclamation, dense-vs-
paged byte identity, recompile guards), engine equivalence with the static
reference, and the WTA vote-concentration property (paper Fig. 6) at the
serving layer."""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch import specs as SP
from repro.models import get_model_fns
from repro.serving import (
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    BlockAllocator,
    RequestState,
    Scheduler,
    ServeConfig,
    ServingEngine,
    StaticServingEngine,
    left_pad,
)

# ---------------------------------------------------------------------------
# Scheduler (pure host logic, no model)
# ---------------------------------------------------------------------------


def test_fifo_admission_order():
    s = Scheduler(n_slots=2)
    rids = [s.submit([1], 4).rid for _ in range(4)]
    admitted = s.admit()
    assert [r.rid for r in admitted] == rids[:2]
    assert [r.slot for r in admitted] == [0, 1]
    assert all(r.state is RequestState.PREFILL for r in admitted)
    assert s.queued() == 2
    # no free slot -> nothing admitted
    assert s.admit() == []
    # free slot 1 -> the NEXT queued rid goes there (FIFO, not LIFO)
    admitted[1].state = RequestState.DECODE
    s.evict(admitted[1], "length")
    refill = s.admit()
    assert [r.rid for r in refill] == [rids[2]]
    assert refill[0].slot == 1


def test_slot_refill_after_eos_eviction():
    s = Scheduler(n_slots=1)
    a = s.submit([1, 2], max_new_tokens=8)
    b = s.submit([3], max_new_tokens=8)
    (req,) = s.admit()
    assert req is a
    s.start_decode(req)
    assert s.record_token(req, 5, eos_token=5) is True
    assert a.state is RequestState.DONE
    assert a.done_reason == "eos"
    assert a.output == [5]
    # the freed slot is immediately refillable by the next queued request
    (req2,) = s.admit()
    assert req2 is b and req2.slot == 0
    assert s.occupancy() == 1.0


def test_left_pad_alignment():
    assert left_pad([1, 2], 5) == [0, 0, 0, 1, 2]
    assert left_pad([1, 2, 3], 3) == [1, 2, 3]
    assert left_pad([], 2) == [0, 0]
    with pytest.raises(ValueError):
        left_pad([1, 2, 3], 2)


def test_eos_negative_never_stops_early():
    """eos_token=-1 (the default) must never match a real token id —
    including token 0, the pad id."""
    s = Scheduler(n_slots=1)
    req = s.submit([1], max_new_tokens=4)
    s.admit()
    s.start_decode(req)
    for tok in (0, -0, 7, 0):
        done = s.record_token(req, tok, eos_token=-1)
    assert done is True
    assert req.done_reason == "length"
    assert req.output == [0, 0, 7, 0]


def test_scheduler_views():
    s = Scheduler(n_slots=4)
    assert not s.has_work()
    r = s.submit([1], 2)
    assert s.has_work() and s.occupancy() == 0.0
    s.admit()
    s.start_decode(r)
    assert s.occupancy() == 0.25
    assert s.active() == [r]
    s.record_token(r, 1, eos_token=-1)
    s.record_token(r, 1, eos_token=-1)
    assert not s.has_work()
    assert s.all_requests() == [r]


# ---------------------------------------------------------------------------
# Block allocator (pure host logic, no model)
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(8, n_reserved=1)
    assert a.capacity == 7 and a.available == 7
    p1 = a.alloc(0, 3)
    p2 = a.alloc(1, 2)
    assert len(p1) == 3 and len(p2) == 2
    assert 0 not in p1 + p2  # page 0 is the reserved trash page
    assert len(set(p1) & set(p2)) == 0
    assert a.available == 2
    assert a.free(0) == 3
    assert a.available == 5
    # freed pages are re-allocatable
    p3 = a.alloc(2, 5)
    assert set(p1) <= set(p3)


def test_allocator_exhaustion_and_misuse():
    a = BlockAllocator(4, n_reserved=1)
    a.alloc(0, 2)
    assert not a.can_alloc(2)
    with pytest.raises(ValueError, match="exhausted"):
        a.alloc(1, 2)
    with pytest.raises(ValueError, match="already holds"):
        a.alloc(0, 1)
    with pytest.raises(KeyError):
        a.free(99)
    with pytest.raises(ValueError):
        BlockAllocator(1, n_reserved=1)  # nothing allocatable


def test_scheduler_admission_gate_preserves_fifo():
    """A gated-out queue head blocks admission entirely — later requests
    must not jump it (that would starve large requests)."""
    s = Scheduler(n_slots=2)
    big = s.submit([1] * 8, 4)
    small = s.submit([2], 4)
    assert s.admit(gate=lambda r: len(r.prompt) < 4) == []
    assert big.state is RequestState.QUEUED
    assert small.state is RequestState.QUEUED
    assert [r.rid for r in s.admit()] == [big.rid, small.rid]


# ---------------------------------------------------------------------------
# ServeConfig validation
# ---------------------------------------------------------------------------


def test_buckets_all_above_max_len_is_loud():
    """Regression: buckets entirely above max_len used to silently filter
    to () and fail obscurely at bucket selection time."""
    cfg = ServeConfig(max_len=32, prefill_buckets=(64, 128))
    with pytest.raises(ValueError, match="max_len"):
        cfg.buckets()


def test_buckets_dedupe_and_partial_filter():
    cfg = ServeConfig(max_len=32, prefill_buckets=(16, 8, 16, 64, 8))
    assert cfg.buckets() == (8, 16)
    with pytest.raises(ValueError):
        ServeConfig(max_len=32, prefill_buckets=(0, 8)).buckets()


def test_engine_validates_buckets_eagerly(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="max_len"):
        ServingEngine(
            params, cfg, ServeConfig(max_len=16, prefill_buckets=(32,))
        )


# ---------------------------------------------------------------------------
# Engine (smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-3b")
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_static_vs_continuous_byte_identical(smoke):
    """With matching padded prompt windows (prompt lengths on the single
    prefill bucket boundary == the static batch max), greedy decoding must
    be byte-identical between the old static path and the scheduler."""
    cfg, params = smoke
    prompts = [
        [5, 6, 7, 1, 2, 3, 4, 9],
        [1, 2, 3],          # mixed length: both engines left-pad to 8
        [9, 8, 7, 6, 5, 4, 3, 2],
    ]
    sc = ServeConfig(
        max_batch=3, max_new_tokens=6, max_len=64, prefill_buckets=(8,)
    )
    cont = ServingEngine(params, cfg, sc)
    stat = StaticServingEngine(params, cfg, sc)
    for p in prompts:
        cont.submit(p)
        stat.submit(p)
    assert cont.step() == stat.step()


def test_mid_flight_slot_refill(smoke):
    """More requests than slots: the queue drains through freed slots and
    every request still completes with its full budget."""
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg, ServeConfig(max_batch=2, max_new_tokens=3, max_len=32)
    )
    rids = [eng.submit([3 + i, 7], max_new_tokens=3) for i in range(5)]
    outs = eng.run()
    assert sorted(outs) == rids
    assert all(len(outs[r]) == 3 for r in rids)
    m = eng.metrics()
    assert m.completed == 5
    assert m.prefills == 5
    assert 0.0 < m.occupancy_mean <= 1.0
    assert m.tokens_per_s > 0
    assert m.ttft_mean > 0


def test_engine_eos_never_stops_early(smoke):
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=2, max_new_tokens=4, max_len=32, eos_token=-1),
    )
    eng.submit([5, 6, 7])
    (out,) = eng.step()
    assert len(out) == 4


def test_engine_eos_evicts_and_truncates(smoke):
    """Learn what the model emits greedily, then declare that token EOS —
    the request must stop at it and the engine must stay healthy."""
    cfg, params = smoke
    probe = ServingEngine(
        params, cfg, ServeConfig(max_batch=1, max_new_tokens=4, max_len=32)
    )
    probe.submit([5, 6, 7])
    (ref,) = probe.step()
    eos = ref[1]  # stop on the second emitted token
    eng = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=1, max_new_tokens=4, max_len=32, eos_token=eos),
    )
    eng.submit([5, 6, 7])
    eng.submit([5, 6, 7])  # refills the slot after the eviction
    outs = eng.step()
    assert len(outs) == 2
    for out in outs:
        assert out == ref[: ref.index(eos) + 1]
    done = eng.sched.all_requests()
    assert all(r.done_reason == "eos" for r in done)


def test_per_request_sampling_invariant_to_batch_composition(smoke):
    """Per-slot PRNG keys: a WTA-sampled request emits the same tokens
    whether it runs alone or alongside other requests."""
    cfg, params = smoke
    wcfg = dataclasses.replace(cfg, wta_head=True)
    sc = ServeConfig(max_batch=3, max_new_tokens=4, max_len=32, seed=11)
    solo = ServingEngine(params, wcfg, sc)
    rid_solo = solo.submit([5, 6, 7])
    out_solo = solo.run()[rid_solo]

    crowd = ServingEngine(params, wcfg, sc)
    rid = crowd.submit([5, 6, 7])  # same rid 0 -> same per-request key
    crowd.submit([1, 2, 3, 4])
    crowd.submit([9])
    out_crowd = crowd.run()[rid]
    assert out_solo == out_crowd


# ---------------------------------------------------------------------------
# Paged KV cache (block pool + block table)
# ---------------------------------------------------------------------------

MIXED_PROMPTS = [
    [5, 6, 7, 1, 2, 3, 4, 9],
    [1, 2, 3],
    [9, 8, 7, 6, 5, 4, 3, 2],
    [4] * 20,
    [11, 12],
    [7] * 13,
]
MIXED_BUDGETS = [6, 9, 3, 12, 5, 7]


def _run_layout(params, cfg, layout, serve_kw=None):
    sc = ServeConfig(
        max_batch=3, max_new_tokens=8, max_len=64, kv_block_size=8,
        kv_layout=layout, **(serve_kw or {}),
    )
    eng = ServingEngine(params, cfg, sc)
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    return eng, eng.run()


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_dense_vs_paged_greedy_byte_identical(arch):
    """The acceptance contract: greedy decode over a mixed-length trace
    (with mid-flight slot refill) must be byte-identical between the dense
    oracle layout and the paged engine — for pure-attention and hybrid
    (attention + recurrent state) families."""
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    _, out_dense = _run_layout(params, cfg, "dense")
    _, out_paged = _run_layout(params, cfg, "paged")
    assert out_dense == out_paged


def test_paged_identity_under_page_recycling(smoke):
    """A pool barely larger than the working set forces freed pages to be
    re-handed to later requests mid-flight; decode must stay byte-identical
    to dense (stale page contents never leak into a live window)."""
    cfg, params = smoke
    _, out_dense = _run_layout(params, cfg, "dense")
    # 3 slots x ceil((8+12)/8)=3 pages + trash, with zero slack for the
    # widest co-resident mix -> constant recycling
    _, out_paged = _run_layout(
        params, cfg, "paged", {"num_kv_blocks": 12}
    )
    assert out_dense == out_paged


def test_pool_exhaustion_backpressures_admission(smoke):
    """With a pool that fits one request at a time, admission must hold
    the second request QUEUED (no crash, no slot leak) until the first
    evicts and frees its pages."""
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=2, max_new_tokens=8, max_len=64, kv_block_size=8,
        kv_layout="paged", num_kv_blocks=4,  # capacity 3 = one request
    )
    eng = ServingEngine(params, cfg, sc)
    r1 = eng.submit([1, 2, 3], 8)   # bucket 8 + 8 -> 2 pages
    r2 = eng.submit([4, 5, 6], 8)
    eng.tick()
    reqs = {r.rid: r for r in eng.sched.all_requests()}
    assert reqs[r1].state is RequestState.DECODE
    assert reqs[r2].state is RequestState.QUEUED  # gated, not crashed
    assert not eng.blocks.can_alloc(2)
    outs = eng.run()  # r1 finishes -> pages freed -> r2 admitted
    assert sorted(outs) == [r1, r2]
    assert len(outs[r1]) == len(outs[r2]) == 8
    assert outs[r1] != [] and eng.blocks.available == eng.blocks.capacity


def test_submit_rejects_request_larger_than_pool(smoke):
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=2, max_new_tokens=8, max_len=64, kv_block_size=8,
        kv_layout="paged", num_kv_blocks=3,  # capacity 2: smallest req fits
    )
    eng = ServingEngine(params, cfg, sc)
    with pytest.raises(ValueError, match="pool"):
        eng.submit([1] * 9, 8)  # bucket 16 + 8 -> 3 pages, capacity is 2


def test_eviction_reclaims_blocks(smoke):
    """Every eviction path (EOS at the engine level is covered elsewhere;
    here budget/length) returns pages: after a drained trace the free list
    holds the full capacity and the table rows all point at trash."""
    cfg, params = smoke
    eng, outs = _run_layout(params, cfg, "paged")
    assert len(outs) == len(MIXED_PROMPTS)
    assert eng.blocks.available == eng.blocks.capacity
    np.testing.assert_array_equal(eng._table, 0)


def test_paged_engine_no_unused_donation_warnings(smoke):
    """serve_step/insert donate the cache buffers so the per-tick update is
    in-place; a layout regression that breaks aliasing shows up as jax's
    'donated buffers were not usable' warning — fail on it."""
    cfg, params = smoke
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "error", message=".*[Dd]onat.*", category=UserWarning
        )
        for layout in ("paged", "dense"):
            _run_layout(params, cfg, layout)


def test_paged_recompile_guard(smoke):
    """Driving a full mixed-length trace costs one compile per (bucket,
    suffix-chunk shape) pair for the chunked-prefill entry point — with
    the default whole-bucket chunks and no prefix overlap, one per
    bucket — and one per decode window width (serve_step); a SECOND
    identical trace through the same engine costs zero new compiles.  No
    per-tick / per-slot / per-page-set / per-start-position recompiles."""
    cfg, params = smoke
    eng, _ = _run_layout(params, cfg, "paged")
    counts = eng.compile_counts()
    buckets_used = {eng._bucket(len(p)) for p in MIXED_PROMPTS}
    assert counts["suffix_prefill"] == len(buckets_used)
    assert counts["state_insert"] == 1  # every completion, one compile
    assert counts["sample0"] == 1
    # window widths are power-of-two bucketed: far fewer than decode steps
    m = eng.metrics()
    assert counts["serve_step"] <= 4
    assert m.decode_steps > counts["serve_step"]
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    eng.run()
    assert eng.compile_counts() == counts, "steady-state trace recompiled"


def test_bad_kv_layout_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="kv_layout"):
        ServingEngine(params, cfg, ServeConfig(kv_layout="flat"))


def test_bad_kv_cache_dtype_is_loud(smoke):
    cfg, params = smoke
    bad = dataclasses.replace(cfg, kv_cache_dtype="fp4")
    with pytest.raises(ValueError, match="kv_cache_dtype"):
        ServingEngine(params, bad, ServeConfig())


def test_bad_kv_block_size_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="kv_block_size"):
        ServingEngine(params, cfg, ServeConfig(kv_block_size=0))


def test_pool_too_small_for_any_request_is_loud(smoke):
    """A num_kv_blocks that could never admit even the smallest request
    (shortest bucket + 1 token) must fail at engine construction, not hang
    the admission gate forever."""
    cfg, params = smoke
    sc = ServeConfig(
        max_len=64, kv_block_size=8, num_kv_blocks=2,
        prefill_buckets=(32,),  # min request needs ceil(33/8)=5 blocks
    )
    with pytest.raises(ValueError, match="admitted"):
        ServingEngine(params, cfg, sc)
    # same pool is fine once the buckets shrink the smallest request
    ServingEngine(
        params, cfg,
        ServeConfig(max_len=64, kv_block_size=8, num_kv_blocks=2,
                    prefill_buckets=(4, 32)),
    )


# ---------------------------------------------------------------------------
# int8 paged KV pool (stochastic-rounded quantized cache)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_int8_paged_matches_bf16_paged_greedy(arch):
    """Acceptance contract: with kv_cache_dtype='int8' the paged engine's
    greedy decode must agree with the bf16 paged path within tolerance —
    on the smoke models the quantization error never flips an argmax, so
    the token streams agree exactly (attention-only and hybrid families)."""
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    _, out_bf16 = _run_layout(params, cfg, "paged")
    _, out_int8 = _run_layout(params, icfg, "paged")
    assert sorted(out_bf16) == sorted(out_int8)
    total = agree = 0
    for rid in out_bf16:
        assert len(out_bf16[rid]) == len(out_int8[rid])
        total += len(out_bf16[rid])
        agree += sum(a == b for a, b in zip(out_bf16[rid], out_int8[rid]))
    assert agree / total >= 0.95, (agree, total)


def test_int8_paged_matches_int8_dense(smoke):
    """Dense-int8 (deterministic nearest rounding) and paged-int8
    (stochastic rounding) are different quantizers of the same cache, so
    token streams agree within tolerance, not byte-for-byte."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    _, out_dense = _run_layout(params, icfg, "dense")
    _, out_paged = _run_layout(params, icfg, "paged")
    total = agree = 0
    for rid in out_dense:
        total += len(out_dense[rid])
        agree += sum(a == b for a, b in zip(out_dense[rid], out_paged[rid]))
    assert agree / total >= 0.95, (agree, total)


def test_int8_paged_identity_under_page_recycling(smoke):
    """Forced page recycling (pool with zero slack) must not leak stale
    codes or stale SCALES into a live window — agreement with the dense
    int8 oracle holds while freed pages are re-handed mid-flight.
    num_kv_blocks=7 is a bf16-block budget → 13 int8 pages, exactly the
    widest co-resident working set of the mixed trace."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    _, out_dense = _run_layout(params, icfg, "dense")
    eng, out_paged = _run_layout(
        params, icfg, "paged", {"num_kv_blocks": 7}
    )
    assert eng.blocks.n_blocks == 13  # doubled budget, trash counted once
    total = agree = 0
    for rid in out_dense:
        total += len(out_dense[rid])
        agree += sum(a == b for a, b in zip(out_dense[rid], out_paged[rid]))
    assert agree / total >= 0.95, (agree, total)
    assert eng.blocks.available == eng.blocks.capacity


def test_int8_pool_doubles_admission_capacity(smoke):
    """At equal num_kv_blocks (a native-dtype memory budget) the int8 pool
    holds twice the pages, so admission takes ~2x the requests — the
    capacity half of the quantization win, visible to BlockAllocator."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")

    def admitted(mcfg):
        sc = ServeConfig(
            max_batch=8, max_new_tokens=8, max_len=64, kv_block_size=8,
            kv_layout="paged", num_kv_blocks=5,
            # identical prompts would ALSO share pages — disable sharing to
            # isolate the dtype-driven capacity factor being pinned here
            enable_prefix_sharing=False,
        )
        eng = ServingEngine(params, mcfg, sc)
        for _ in range(8):
            eng.submit([1, 2, 3], 8)  # 2 blocks each
        eng.tick()
        return sum(
            1 for r in eng.sched.all_requests()
            if r.state is not RequestState.QUEUED
        )

    n16, n8 = admitted(cfg), admitted(icfg)
    assert n16 == 2 and n8 == 4  # capacity 4 vs 9 blocks, 2 per request


def test_int8_paged_recompile_guard(smoke):
    """The int8 layout keeps the compile discipline: one compile per
    (bucket, chunk shape) pair for the chunked prefill (the per-block
    rounding-seed vector is traced) and one per decode window bucket,
    zero new compiles on a repeat trace."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    eng, _ = _run_layout(params, icfg, "paged")
    counts = eng.compile_counts()
    buckets_used = {eng._bucket(len(p)) for p in MIXED_PROMPTS}
    assert counts["suffix_prefill"] == len(buckets_used)
    m = eng.metrics()
    assert counts["serve_step"] <= 4
    assert m.decode_steps > counts["serve_step"]
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    eng.run()
    assert eng.compile_counts() == counts, "steady-state trace recompiled"


def test_int8_paged_no_unused_donation_warnings(smoke):
    """The scale planes must stay donation-aliasable like the code pools."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "error", message=".*[Dd]onat.*", category=UserWarning
        )
        _run_layout(params, icfg, "paged")


# ---------------------------------------------------------------------------
# Prefix sharing + copy-on-write (content-hash block dedup in the paged pool)
# ---------------------------------------------------------------------------

# repeated-prefix trace: the first/second/fourth prompts are identical, the
# third differs, mixed budgets — sharing must dedup the repeats only
SHARED_PROMPTS = [
    [1, 2, 3, 4, 5, 6, 7, 8],
    [1, 2, 3, 4, 5, 6, 7, 8],
    [9, 9, 9],
    [1, 2, 3, 4, 5, 6, 7, 8],
    [9, 9, 9],
]
SHARED_BUDGETS = [6, 4, 6, 3, 5]


def _run_sharing(params, cfg, share, serve_kw=None):
    kw = dict(
        max_batch=3, max_new_tokens=8, max_len=64, kv_block_size=8,
        kv_layout="paged", enable_prefix_sharing=share,
    )
    kw.update(serve_kw or {})
    sc = ServeConfig(**kw)
    eng = ServingEngine(params, cfg, sc)
    for p, b in zip(SHARED_PROMPTS, SHARED_BUDGETS):
        eng.submit(p, b)
    return eng, eng.run()


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_prefix_sharing_byte_identical(arch):
    """The acceptance contract: greedy decode over a repeated-prefix trace
    must be byte-identical with prefix sharing on vs off — full-hit
    admissions replay the stored last-token logits and state leaves of the
    original prefill, which are bit-equal to what their own prefill would
    have produced.  Covers attention-only and hybrid (recurrent-state)
    families; sharing must actually fire (prefill skipped at least once)."""
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    eng_on, out_on = _run_sharing(params, cfg, True)
    eng_off, out_off = _run_sharing(params, cfg, False)
    assert out_on == out_off
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_off.prefix_hits == 0
    assert m_on.prefix_hits >= 1
    assert m_on.prefills == m_off.prefills - m_on.prefix_hits


def test_prefix_sharing_wta_sampling_stays_per_request(smoke):
    """A full-hit admission samples its first token from STORED logits with
    its OWN per-request key — WTA vote noise must stay a function of
    (rid, step), not of whether the prefill was shared."""
    cfg, params = smoke
    wcfg = dataclasses.replace(cfg, wta_head=True)
    eng_on, out_on = _run_sharing(params, wcfg, True, {"seed": 11})
    _, out_off = _run_sharing(params, wcfg, False, {"seed": 11})
    assert out_on == out_off
    assert eng_on.metrics().prefix_hits >= 1


def test_prefix_sharing_cow_fork_mid_decode(smoke):
    """An unaligned bucket (8-token prompts, 16-token blocks) leaves the
    boundary block partially filled; identical prompts admitted in the
    same tick share it, and the first decode write must copy-on-write fork
    every sharer onto its reserved spare page — with decode staying
    byte-identical to the sharing-off engine."""
    cfg, params = smoke
    kw = {"kv_block_size": 16, "prefill_buckets": (8, 32)}
    eng_on, out_on = _run_sharing(params, cfg, True, kw)
    eng_off, out_off = _run_sharing(params, cfg, False, kw)
    assert out_on == out_off
    m = eng_on.metrics()
    assert m.cow_forks >= 1
    assert m.prefix_hits >= 1
    assert eng_off.metrics().cow_forks == 0
    # every spare was either spent on a fork or returned at eviction
    assert eng_on.blocks.available == eng_on.blocks.capacity


def test_prefix_sharing_page_recycling_of_formerly_shared_block(smoke):
    """Once every owner of a shared block is evicted the page returns to
    the free list AND its index entry dies with it: a later request with a
    different prompt recycles the physical page, and a later request with
    the ORIGINAL prompt must re-prefill (a stale hit would hand it the
    recycled content).  Byte-identity against sharing-off pins that no
    stale content leaks through either path."""
    cfg, params = smoke
    shared = [1, 2, 3, 4, 5, 6, 7, 8]

    def drive(share):
        sc = ServeConfig(
            max_batch=2, max_new_tokens=8, max_len=64, kv_block_size=8,
            kv_layout="paged", num_kv_blocks=7,  # zero-slack working set
            enable_prefix_sharing=share,
        )
        eng = ServingEngine(params, cfg, sc)
        rids = [eng.submit(shared, 4), eng.submit(shared, 4)]
        while eng.sched.has_work():
            eng.tick()
        # both owners gone: the pool must be fully reclaimed, index empty
        assert eng.blocks.available == eng.blocks.capacity
        assert not eng.blocks.registered_pages()
        rids.append(eng.submit([4] * 12, 6))   # recycles the freed pages
        rids.append(eng.submit(shared, 4))     # the formerly shared prompt
        outs = eng.run()
        return eng, [outs[r] for r in rids]

    eng_on, out_on = drive(True)
    _, out_off = drive(False)
    assert out_on == out_off
    m = eng_on.metrics()
    assert m.prefix_hits == 1           # only the co-resident pair shared
    assert m.prefills == len(out_on) - 1


@pytest.mark.parametrize("dtype", ["same", "int8"])
def test_prefix_sharing_partial_hit_shares_leading_blocks(smoke, dtype):
    """Two same-length prompts agreeing on their first block (but not the
    second) share exactly that block: the sharer prefills ONLY its suffix
    (no full hit, but a partial hit that skips the matched block's
    tokens) and maps the resident page — its table row aliases the
    original's at block 0 and diverges at block 1 — with decode
    byte-identical to sharing-off.  Works for int8 pools because block
    seeds are content-derived, so the sharer's own prefill would have
    written the identical codes it is instead aliasing."""
    cfg, params = smoke
    if dtype == "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=dtype)
    a = list(range(1, 17))
    b = list(range(1, 9)) + [20, 21, 22, 23, 24, 25, 26, 27]

    def drive(share):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(
                max_batch=2, max_new_tokens=6, max_len=64, kv_block_size=8,
                enable_prefix_sharing=share,
            ),
        )
        rids = [eng.submit(a, 6), eng.submit(b, 6)]
        # chunked prefill interleaves: tick until both jobs published
        # their table rows (request b's job runs a tick after a's)
        while not all(
            r.state is RequestState.DECODE for r in eng.sched.all_requests()
        ):
            eng.tick()
        tables = eng._table.copy()
        outs = eng.run()
        return eng, tables, [outs[r] for r in rids]

    eng_on, t_on, out_on = drive(True)
    _, t_off, out_off = drive(False)
    assert out_on == out_off
    m = eng_on.metrics()
    assert m.prefix_hits == 0           # partial ≠ full hit
    assert m.prefix_partial_hits == 1   # request b mapped block 0
    # the attention-only smoke family resumes at the full matched depth:
    # request b computed only its 8-token suffix
    assert m.prefill_tokens_saved == 8
    assert m.prefill_tokens == 16 + 8
    assert t_on[0, 0] == t_on[1, 0], "leading block not shared"
    assert t_on[0, 1] != t_on[1, 1], "diverging block wrongly shared"
    assert t_off[0, 0] != t_off[1, 0]


def test_prefix_sharing_int8_within_quant_tolerance(smoke):
    """int8 pools: block quantization seeds derive from block CONTENT
    (chain hash), not the request id, so a shared block's codes are
    bit-identical to what the sharer's own prefill would have written —
    sharing on vs off stays within quantization tolerance (on this smoke
    trace the schedules coincide, so the streams agree exactly)."""
    cfg, params = smoke
    icfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    eng_on, out_on = _run_sharing(params, icfg, True)
    _, out_off = _run_sharing(params, icfg, False)
    assert sorted(out_on) == sorted(out_off)
    assert eng_on.metrics().prefix_hits >= 1
    total = agree = 0
    for rid in out_off:
        assert len(out_on[rid]) == len(out_off[rid])
        total += len(out_off[rid])
        agree += sum(a == b for a, b in zip(out_on[rid], out_off[rid]))
    assert agree / total >= 0.95, (agree, total)


def test_prefix_sharing_recompile_guard(smoke):
    """Shared-prefix admission and COW forks add ZERO compilations beyond
    the existing per-bucket/per-window set plus the three one-time
    sharing entry points (state insert, page copy, stored-logits
    sampler) — and a repeat trace through the same engine compiles
    nothing new at all."""
    cfg, params = smoke
    kw = {"kv_block_size": 16, "prefill_buckets": (8, 32)}  # forces a fork
    eng, _ = _run_sharing(params, cfg, True, kw)
    counts = eng.compile_counts()
    buckets_used = {eng._bucket(len(p)) for p in SHARED_PROMPTS}
    assert counts["suffix_prefill"] == len(buckets_used)
    assert counts["serve_step"] <= 4
    assert counts["state_insert"] == 1  # bucket-independent, one compile
    assert counts["page_copy"] == 1     # at least one fork, one compile
    assert counts["sample0"] == 1
    for p, b in zip(SHARED_PROMPTS, SHARED_BUDGETS):
        eng.submit(p, b)
    eng.run()
    assert eng.compile_counts() == counts, "steady-state trace recompiled"


def test_prefix_sharing_raises_admission_capacity(smoke):
    """Acceptance contract, capacity half: at equal num_kv_blocks a
    repeated-prefix burst admits strictly more requests with sharing on —
    each repeat maps the resident prompt blocks and only allocates its
    decode-budget pages."""
    cfg, params = smoke

    def admitted(share):
        sc = ServeConfig(
            max_batch=8, max_new_tokens=8, max_len=64, kv_block_size=8,
            kv_layout="paged", num_kv_blocks=8,
            enable_prefix_sharing=share,
        )
        eng = ServingEngine(params, cfg, sc)
        for _ in range(8):
            eng.submit(list(range(1, 17)), 8)  # bucket 16 + 8 → 3 blocks
        eng.tick()
        return sum(
            1 for r in eng.sched.all_requests()
            if r.state is not RequestState.QUEUED
        )

    # capacity 7: off fits floor(7/3)=2 requests; on fits the original (3
    # pages) + 4 repeats (1 fresh decode page each) = 5
    assert admitted(False) == 2
    assert admitted(True) == 5


def test_prefix_sharing_validation_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="enable_prefix_sharing"):
        ServingEngine(
            params, cfg, ServeConfig(enable_prefix_sharing="off")
        )


def test_prefix_sharing_random_trace_equivalence(smoke):
    """Engine-level property check: random repeated-prefix traces under a
    tight pool must decode byte-identically with sharing on vs off (greedy
    outputs are schedule-invariant, so even admission-order divergence
    from the capacity win cannot change them), with allocator invariants
    re-checked after every tick."""
    import random as _random

    from test_prefix_sharing import check_invariants

    cfg, params = smoke
    templates = [
        [1, 2, 3, 4, 5, 6, 7, 8], [4] * 12, [9, 9, 9], [1, 2, 3, 4],
    ]
    for seed in (0, 1, 2):
        rng = _random.Random(seed)
        reqs = [
            (list(rng.choice(templates)), rng.randint(2, 8))
            for _ in range(7)
        ]

        def drive(share):
            eng = ServingEngine(
                params, cfg,
                ServeConfig(
                    max_batch=3, max_new_tokens=8, max_len=64,
                    kv_block_size=8, num_kv_blocks=10,
                    enable_prefix_sharing=share,
                ),
            )
            rids = [eng.submit(p, b) for p, b in reqs]
            while eng.sched.has_work():
                eng.tick()
                check_invariants(eng.blocks)
            outs = {
                r.rid: r.output
                for r in eng.sched.all_requests()
            }
            assert eng.blocks.available == eng.blocks.capacity
            return [outs[r] for r in rids]

        assert drive(True) == drive(False), f"trace seed {seed} diverged"


# ---------------------------------------------------------------------------
# Suffix-only prefill + chunked, interleaved prefill scheduling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_partial_sharing_byte_identity(arch):
    """Acceptance contract for suffix-only prefill: a trace of prompts
    sharing a long common prefix (but NOT full prompts) decodes
    byte-identically with sharing on vs off, while sharing-on computes
    only the suffixes.  ``prefill_chunk=8`` gives the hybrid family a
    chunk grid whose boundary states are stashed, so recurrent-state
    models get suffix resumes too — not just the attention-only family."""
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    head = list(range(1, 17))  # 16 shared real tokens
    prompts = [
        head + [30 + i] * 8 for i in range(3)  # 24 tokens, bucket 32
    ]

    def drive(share):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(
                max_batch=3, max_new_tokens=6, max_len=64, kv_block_size=8,
                prefill_chunk=8, enable_prefix_sharing=share,
            ),
        )
        rids = [eng.submit(p, 6) for p in prompts]
        outs = eng.run()
        return eng, [outs[r] for r in rids]

    eng_on, out_on = drive(True)
    eng_off, out_off = drive(False)
    assert out_on == out_off
    m_on, m_off = eng_on.metrics(), eng_off.metrics()
    assert m_off.prefix_partial_hits == 0
    assert m_off.prefill_tokens_saved == 0
    # padded prompts agree on 8 pad + 16 head = 24 tokens = 3 blocks; the
    # two repeats each resume at 24 (a chunk boundary, so the hybrid
    # family's stored state snapshot is used)
    assert m_on.prefix_partial_hits == 2
    assert m_on.prefill_tokens_saved == 2 * 24
    assert m_on.prefill_tokens == m_off.prefill_tokens - 2 * 24
    assert m_on.prefix_hits == 0  # no full hits in this trace


def test_chunked_prefill_interleaves_with_decode(smoke):
    """A long prompt's prefill spreads over multiple ticks (one chunk per
    tick) while an in-flight request keeps emitting a token EVERY tick —
    the TTFT-jitter bound chunking exists for.  Token streams stay
    byte-identical to the unchunked engine."""
    cfg, params = smoke

    def drive(chunk):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(
                max_batch=2, max_new_tokens=10, max_len=64,
                kv_block_size=8, prefill_chunk=chunk,
            ),
        )
        # disjoint token ranges: no padded block of r1 can match r0's
        r0 = eng.submit([50, 51, 52], 10)
        eng.tick()  # r0 prefills (one 8-token bucket = one chunk), decodes
        r1 = eng.submit(list(range(1, 28)), 4)  # bucket 32 -> 4 chunks
        decode_ticks = 0
        while eng.sched.request(r1).state is not RequestState.DECODE:
            before = len(eng.sched.request(r0).output)
            eng.tick()
            decode_ticks += len(eng.sched.request(r0).output) - before
        outs = eng.run()
        return decode_ticks, [outs[r] for r in (r0, r1)]

    ticks_chunked, outs_chunked = drive(8)
    ticks_mono, outs_mono = drive(0)
    assert outs_chunked == outs_mono
    # r1's prefill took 4 ticks (4 chunks); r0 decoded through every one
    assert ticks_chunked >= 4
    assert ticks_mono <= 2


def test_chunked_prefill_recompile_guard(smoke):
    """Chunked prefill compiles once per (bucket, chunk shape) pair — the
    start position, page ids, slot and seeds are traced — and a repeat
    trace (including the partial-hit suffix shapes) compiles nothing."""
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg,
        ServeConfig(
            max_batch=2, max_new_tokens=4, max_len=64, kv_block_size=8,
            prefill_chunk=16,
        ),
    )

    def trace():
        rids = [
            # long budget: still resident when the third request arrives
            eng.submit(list(range(1, 25)), 16),  # bucket 32: 16+16 chunks
            eng.submit(list(range(1, 7)), 4),    # bucket 8: one 8 chunk
            # shares 3 padded blocks with the first prompt (8 pad + 16
            # head); admitted into the second slot after the short
            # request evicts, while the first is still decoding
            eng.submit(list(range(1, 17)) + [40] * 8, 4),
        ]
        eng.run()
        return rids

    trace()
    counts = eng.compile_counts()
    # three (bucket, chunk-shape) pairs: bucket-32 cold runs as two
    # 16-token chunks (ONE compile), bucket 8 as one whole-bucket chunk,
    # and the partial hit (24 matched tokens: 8 pad + 16 head = 3 blocks)
    # resumes mid-grid with an 8-token tail chunk [24, 32)
    assert counts["suffix_prefill"] == 3, counts
    m = eng.metrics()
    assert m.prefix_partial_hits == 1
    assert m.prefill_tokens_saved == 24
    trace()
    assert eng.compile_counts() == counts, "repeat trace recompiled"


def test_prefill_chunk_validation_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(
            params, cfg, ServeConfig(kv_block_size=8, prefill_chunk=12)
        )
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(params, cfg, ServeConfig(prefill_chunk=-8))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(
            params, cfg, ServeConfig(kv_layout="dense", prefill_chunk=16)
        )


def test_demoted_full_hit_does_not_corrupt_registrant(smoke):
    """Regression: a full-hit job that loses its stored payload while
    queued (the registrant's first decode write in-place-diverges the
    partial boundary block, deregistering it) demotes to a boundary-block
    recompute — which must COW-fork the now-diverged shared page onto the
    job's reserved spare instead of rewriting it in place, or the
    registrant's live decode K/V rows get zeroed and its token stream
    silently diverges from the sharing-off engine.

    The trace forces the window: R1 (unaligned 12-token bucket) admits
    and completes first; M occupies the one compute chunk of the next
    tick so R2 (identical to R1, full hit stashed with no payload yet)
    waits in the FIFO while R1's decode kills the terminal index entry."""
    cfg, params = smoke
    kw = dict(
        max_batch=3, max_new_tokens=8, max_len=64, kv_block_size=8,
        prefill_buckets=(12, 16),
    )
    prompts = [
        list(range(1, 13)),    # R1: bucket 12, partial boundary block
        list(range(20, 36)),   # M: bucket 16, blocks the compute slot
        list(range(1, 13)),    # R2: full match on R1, demotes later
    ]

    def drive(share):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(**kw, enable_prefix_sharing=share),
        )
        rids = [eng.submit(p, 8) for p in prompts]
        outs = eng.run()
        return eng, [outs[r] for r in rids]

    eng_on, out_on = drive(True)
    _, out_off = drive(False)
    assert out_on == out_off
    m = eng_on.metrics()
    assert m.prefix_partial_hits >= 1  # the demotion actually fired
    assert m.cow_forks >= 1            # ...and forked, not rewrote
    assert eng_on.blocks.available == eng_on.blocks.capacity


def test_full_hit_on_boundary_snapshot_demotes_not_crashes(smoke):
    """Regression: a short prompt that IS the shared prefix of a longer
    in-flight prompt full-matches blocks whose terminal hash carries only
    the longer prompt's logits-less chunk-boundary snapshot.  The engine
    must demote that job to a suffix recompute (never feed None logits to
    the sampler) and republish terminal logits on the hash, so a LATER
    identical short prompt full-hits properly — with every token stream
    byte-identical to sharing-off."""
    cfg, params = smoke
    kw = dict(
        max_batch=3, max_new_tokens=6, max_len=64, kv_block_size=8,
        prefill_buckets=(16, 32), prefill_chunk=16,
    )

    def drive(share):
        eng = ServingEngine(
            params, cfg, ServeConfig(**kw, enable_prefix_sharing=share)
        )
        a = eng.submit(list(range(1, 25)), 6)  # bucket 32: [0]*8 + 1..24
        eng.tick()  # A's first chunk [0, 16) stashes (None, state)
        # B's padded prompt ([0]*8 + 1..8) == A's first 16 padded tokens:
        # B full-matches A's blocks but the terminal payload has no logits
        b = eng.submit(list(range(1, 9)), 6)
        c = eng.submit(list(range(1, 9)), 6)  # repeat of B
        outs = eng.run()
        return eng, [outs[r] for r in (a, b, c)]

    eng_on, out_on = drive(True)
    _, out_off = drive(False)
    assert out_on == out_off
    m = eng_on.metrics()
    assert m.prefix_partial_hits >= 1  # B demoted to a suffix recompute
    assert m.prefix_hits >= 1          # C full-hit on B's republished logits


def test_admission_gate_refusal_has_no_side_effects(smoke):
    """Directed regression for the admission-gate audit: a gate that
    REFUSES (pool exhausted) must leave the allocator bit-for-bit
    untouched — no refcount bump on the probed/matched pages, no index
    mutation — even when the refused request had a partial prefix match.
    A True gate bumps exactly the pages it maps (its owned list)."""
    from repro.serving.scheduler import prefix_block_hashes

    cfg, params = smoke
    sc = ServeConfig(
        max_batch=2, max_new_tokens=8, max_len=64, kv_block_size=8,
        num_kv_blocks=6,  # capacity 5: the first request takes 3
    )
    eng = ServingEngine(params, cfg, sc)
    head = list(range(1, 17))  # bucket 16, block-aligned: 2 prompt blocks
    r1 = eng.submit(head, 8)   # + 8 budget tokens -> 3 blocks
    eng.tick()                 # admitted, prefilled, decoding
    hashes = [h for h, _ in prefix_block_hashes(head, 8)]
    matched = eng.blocks.longest_prefix_match(hashes)
    assert len(matched) == 2   # r1's prompt blocks are resident
    refs_before = {p: eng.blocks.refcount(p) for p in matched}
    index_before = eng.blocks.registered_pages()
    # same head, bigger budget: matches both prompt blocks but needs 3
    # fresh decode pages when only 2 remain -> the gate must refuse
    # without touching anything
    r2 = eng.submit(head, 24)
    eng.tick()
    assert eng.sched.request(r2).state is RequestState.QUEUED
    assert {p: eng.blocks.refcount(p) for p in matched} == refs_before
    assert eng.blocks.registered_pages() == index_before
    outs = eng.run()  # r1 evicts -> r2 admits and completes
    assert sorted(outs) == [r1, r2]


# ---------------------------------------------------------------------------
# WTA majority-vote concentration (paper Fig. 6 at the serving layer)
# ---------------------------------------------------------------------------


def test_wta_vote_concentration_with_trials(smoke):
    """As the trial count T grows, the majority vote concentrates on the
    argmax token — the paper's accuracy-recovery mechanism, exercised
    through the serving sampler (`sample_tokens`) with per-slot keys."""
    cfg, _ = smoke
    z = jnp.asarray(
        [0.0, -0.5, 0.3, 2.0, 0.8, -1.0, 0.5, -0.2,
         0.1, -0.8, 0.4, 0.0, -0.3, 0.6, -0.6, 0.2],
        jnp.float32,
    )
    target = int(jnp.argmax(z))
    n_samples = 256
    logits = jnp.broadcast_to(z, (n_samples, z.shape[0]))
    base = jax.random.PRNGKey(123)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n_samples)
    )
    steps = jnp.zeros((n_samples,), jnp.int32)

    rates = {}
    for trials in (1, 16, 256):
        wcfg = dataclasses.replace(
            cfg,
            wta_head=True,
            analog=dataclasses.replace(cfg.analog, wta_trials=trials),
        )
        toks = SP.sample_tokens(wcfg, logits, keys, steps)
        rates[trials] = float(jnp.mean(toks == target))
    # monotone concentration (with sampling slack) ... Fig. 6 mechanism
    assert rates[16] > rates[1] - 0.05
    assert rates[256] > rates[16] - 0.05
    assert rates[256] > 0.9, rates
    assert rates[256] > rates[1] + 0.1, rates


# ---------------------------------------------------------------------------
# Host-bookkeeping bug sweep + sharded decode over the (data, model) mesh
# ---------------------------------------------------------------------------


def test_evict_severs_slot_binding():
    """Refill-reuse regression: eviction must null the DONE request's live
    ``slot`` binding (keeping the historical slot as ``done_slot``), so a
    done record can never alias the per-slot state of whichever request
    refills the slot next."""
    s = Scheduler(n_slots=1)
    a = s.submit([1, 2], max_new_tokens=1)
    b = s.submit([3, 4], max_new_tokens=1)
    (req,) = s.admit()
    s.start_decode(req)
    assert s.record_token(req, 5, eos_token=-1) is True
    assert a.state is RequestState.DONE
    assert a.slot is None          # live binding severed
    assert a.done_slot == 0        # history survives for metrics/debug
    (req2,) = s.admit()
    assert req2 is b and req2.slot == 0
    assert a.slot != req2.slot     # DONE record does not alias the reuse


def test_submit_rejects_empty_prompt(smoke):
    """An empty prompt would left-pad to an all-pad window and decode from
    a pad token's logits — garbage that previously sailed through."""
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg, ServeConfig(max_batch=1, max_new_tokens=2, max_len=32)
    )
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    assert not eng.sched.has_work()


def test_mesh_validation_is_loud(smoke):
    cfg, params = smoke
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(model=1, data=1)
    with pytest.raises(ValueError, match="paged-layout knob"):
        ServingEngine(
            params, cfg,
            ServeConfig(
                max_batch=1, max_new_tokens=2, max_len=32,
                kv_layout="dense", mesh=mesh,
            ),
        )
    bad = jax.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    with pytest.raises(ValueError, match=r"\('data', 'model'\) axes"):
        ServingEngine(
            params, cfg,
            ServeConfig(
                max_batch=1, max_new_tokens=2, max_len=32,
                kv_layout="paged", mesh=bad,
            ),
        )


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_sharded_1x1_mesh_byte_identity(arch):
    """The sharded-decode acceptance contract: an engine on a 1×1
    ``(data, model)`` mesh must be BYTE-identical to ``mesh=None`` over
    the full mixed-length trace (admission, refill, page recycling) —
    for pure-attention and hybrid recurrent families."""
    from repro.launch.mesh import make_host_mesh

    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    _, base = _run_layout(params, cfg, "paged")
    _, shard = _run_layout(
        params, cfg, "paged", {"mesh": make_host_mesh(model=1, data=1)}
    )
    assert base == shard


def test_sharded_recompile_guard(smoke):
    """The mesh-aware entry points keep the compile discipline of the
    single-device engine: one suffix-prefill compile per bucket, windowed
    serve_step compiles, zero new compiles on a repeat trace."""
    from repro.launch.mesh import make_host_mesh

    cfg, params = smoke
    eng, _ = _run_layout(
        params, cfg, "paged", {"mesh": make_host_mesh(model=1, data=1)}
    )
    counts = eng.compile_counts()
    buckets_used = {eng._bucket(len(p)) for p in MIXED_PROMPTS}
    assert counts["suffix_prefill"] == len(buckets_used)
    assert counts["state_insert"] == 1
    assert counts["serve_step"] <= 4
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    eng.run()
    assert eng.compile_counts() == counts, "steady-state trace recompiled"


# ---------------------------------------------------------------------------
# Preemption, priorities, deadlines & KV spill/restore
# ---------------------------------------------------------------------------


def test_priority_admission_order():
    """Interactive (priority 0) jumps the queue ahead of earlier batch
    submissions; within a class, FIFO by rid."""
    s = Scheduler(n_slots=1)
    b0 = s.submit([1], 2, priority=PRIORITY_BATCH)
    b1 = s.submit([2], 2, priority=PRIORITY_BATCH)
    i0 = s.submit([3], 2, priority=PRIORITY_INTERACTIVE)
    assert s.peek() is i0
    (req,) = s.admit()
    assert req is i0
    s.start_decode(req)
    s.evict(req, "length")
    (nxt,) = s.admit()
    assert nxt is b0 and s.peek() is b1


def test_requeue_roundtrip():
    """requeue frees the slot, returns the request to QUEUED, and bumps
    the preemption counter; the next admit re-seats it."""
    s = Scheduler(n_slots=1)
    a = s.submit([1, 2], 4)
    (req,) = s.admit()
    s.start_decode(req)
    s.record_token(req, 7, eos_token=-1)
    s.requeue(req)
    assert a.state is RequestState.QUEUED
    assert a.slot is None
    assert a.preemptions == 1
    assert a.output == [7]  # decoded tokens survive the round trip
    (req2,) = s.admit()
    assert req2 is a and a.slot == 0


def test_cancel_and_expired():
    s = Scheduler(n_slots=1)
    a = s.submit([1], 4, now=0.0, deadline_ms=50.0)
    b = s.submit([2], 4, now=0.0)  # no deadline: never expires
    assert s.expired(now=0.040) == []
    assert s.expired(now=0.060) == [a]
    s.cancel(a, "deadline", now=0.060)
    assert a.state is RequestState.DONE and a.done_reason == "deadline"
    assert s.expired(now=99.0) == []  # DONE requests never re-expire
    (req,) = s.admit()
    assert req is b


def _preempt_fixture(arch, injector=None, **kw):
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(
        max_batch=2, max_new_tokens=10, max_len=64, kv_block_size=8,
        prefill_buckets=(16,), fault_injector=injector, **kw,
    )
    return cfg, params, ServingEngine(params, cfg, sc)


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_preempt_restore_byte_identity(arch):
    """The spill/restore acceptance contract: a request preempted
    mid-decode (pages spilled to host, slot freed, later restored through
    the normal admission gate) must emit a token stream BYTE-identical to
    an un-preempted run — attention-only and hybrid recurrent families."""
    from repro.serving import FaultInjector

    inj = FaultInjector().at(4, "preempt").at(8, "preempt")
    cfg, params, eng = _preempt_fixture(arch, injector=inj)
    prompts = [list(range(1, 10)), list(range(2, 14))]
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    m = eng.metrics()
    assert m.preemptions == 2 and m.restores == 2
    assert inj.applied and all(k == "preempt" for _, k, _ in inj.applied)

    _, _, ref = _preempt_fixture(arch)
    ref_rids = [ref.submit(p, 10) for p in prompts]
    ref_out = ref.run()
    for r, rr in zip(rids, ref_rids):
        assert out[r] == ref_out[rr], arch


def test_preempt_restore_compile_counts():
    """Spill, restore, and slot-state gather are one compile each — page
    ids are fixed-width (trash-padded), so every preemption depth reuses
    the same trace; a repeat preemption compiles nothing new."""
    from repro.serving import FaultInjector

    inj = FaultInjector().at(3, "preempt").at(7, "preempt")
    _, _, eng = _preempt_fixture("stablelm-3b", injector=inj)
    for p in ([1, 2, 3, 4], list(range(2, 14))):
        eng.submit(p, 10)
    eng.run()
    counts = eng.compile_counts()
    assert counts["page_spill"] == 1
    assert counts["page_restore"] == 1
    assert counts["state_gather"] == 1
    assert counts["serve_step"] <= 4


def test_higher_priority_arrival_preempts_lowest(smoke):
    """A tight pool running a batch request back-pressures an interactive
    arrival; with preemption on, the batch victim spills, the interactive
    request takes the pool, and the victim restores and STILL finishes
    byte-identically."""
    cfg, params = smoke

    def run(enable):
        sc = ServeConfig(
            max_batch=1, max_new_tokens=6, max_len=64, kv_block_size=8,
            prefill_buckets=(16,), num_kv_blocks=7,
            enable_preemption=enable,
        )
        eng = ServingEngine(params, cfg, sc)
        rb = eng.submit(list(range(1, 10)), 6, priority=PRIORITY_BATCH)
        for _ in range(3):
            eng.tick()
        ri = eng.submit(
            list(range(3, 12)), 6, priority=PRIORITY_INTERACTIVE
        )
        n = 0
        while eng.sched.has_work() and n < 300:
            eng.tick()
            n += 1
        return eng, rb, ri

    eng_on, rb, ri = run(True)
    m = eng_on.metrics()
    assert m.preemptions >= 1 and m.restores >= 1
    b_on = eng_on.sched.request(rb)
    assert b_on.preemptions >= 1
    # interactive finished BEFORE the preempted batch request
    i_done = eng_on.sched.request(ri).done_time
    assert i_done is not None and i_done < b_on.done_time

    eng_off, rb2, _ = run(False)
    assert eng_off.metrics().preemptions == 0
    # the preempted run's batch stream matches the unpreempted one
    assert b_on.output == eng_off.sched.request(rb2).output


def test_uniform_priority_never_preempts(smoke):
    """A victim must have STRICTLY lower priority than the arrival —
    single-class traffic under pool pressure back-pressures (PR-3
    behavior) instead of thrashing."""
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=2, max_new_tokens=6, max_len=64, kv_block_size=8,
        prefill_buckets=(16,), num_kv_blocks=7,
    )
    eng = ServingEngine(params, cfg, sc)
    for i in range(3):
        eng.submit(list(range(1 + i, 10 + i)), 6)
    eng.run()
    assert eng.metrics().preemptions == 0
    assert eng.metrics().completed == 3


def test_deadline_eviction_mid_stream(smoke):
    """A request whose deadline lapses mid-decode is evicted with reason
    ``"deadline"`` and its pool pages are reclaimed."""
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=1, max_new_tokens=200, max_len=256, kv_block_size=8,
        prefill_buckets=(16,),
    )
    eng = ServingEngine(params, cfg, sc)
    rid = eng.submit(list(range(1, 10)), 200, deadline_ms=1e-3)
    eng.run()
    req = eng.sched.request(rid)
    assert req.done_reason == "deadline"
    assert eng.blocks.available == eng.blocks.capacity
    assert eng.metrics().evictions.get("deadline") == 1


def test_queued_deadline_eviction_without_slot(smoke):
    """Expiry must also reap QUEUED requests that never got a slot."""
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=1, max_new_tokens=4, max_len=64, kv_block_size=8,
        prefill_buckets=(16,),
    )
    eng = ServingEngine(params, cfg, sc)
    r0 = eng.submit(list(range(1, 10)), 4)
    r1 = eng.submit(list(range(2, 12)), 4, deadline_ms=1e-3)
    eng.run()
    assert eng.sched.request(r0).done_reason == "length"
    assert eng.sched.request(r1).done_reason == "deadline"
    assert len(eng.sched.request(r1).output) == 0


def test_metrics_latency_by_class(smoke):
    """Per-priority-class TTFT/latency percentiles and the eviction-reason
    counters surface in metrics() and row()."""
    cfg, params = smoke
    sc = ServeConfig(
        max_batch=2, max_new_tokens=4, max_len=64, kv_block_size=8,
        prefill_buckets=(16,),
    )
    eng = ServingEngine(params, cfg, sc)
    eng.submit(list(range(1, 10)), 4, priority=PRIORITY_INTERACTIVE)
    eng.submit(list(range(2, 12)), 4, priority=PRIORITY_BATCH)
    eng.run()
    m = eng.metrics()
    assert set(m.latency_by_class) == {PRIORITY_INTERACTIVE, PRIORITY_BATCH}
    for cls in m.latency_by_class.values():
        assert cls["n"] == 1
        assert 0 < cls["ttft_p50_ms"] <= cls["ttft_p99_ms"]
        assert 0 < cls["latency_p50_ms"] <= cls["latency_p99_ms"]
    assert m.ttft_p50 <= m.ttft_p99
    row = m.row()
    assert "ttft_p99_ms=" in row
    # row() must surface the per-class view it used to drop: one compact
    # class=... entry per priority with its n and latency p99
    assert "class=" in row
    for pr, cls in m.latency_by_class.items():
        assert f"{pr}:n={cls['n']}" in row
    # the analog energy accounting rides along in row() too
    assert "raca_pj_per_tok=" in row and "adc1b_pj_per_tok=" in row
    # done-reason counts: both requests spent their budget normally
    assert m.evictions == {"length": 2}


def test_metrics_row_compact_renderings():
    """ServingMetrics.row() unit-level: optional sections render only when
    non-empty, with the documented compact shapes."""
    from repro.serving.engine import ServingMetrics

    bare = ServingMetrics()
    assert "class=" not in bare.row()
    assert "raca_pj_per_tok=" not in bare.row()
    m = ServingMetrics(
        latency_by_class={
            0: {"n": 2, "ttft_p50_ms": 1.0, "ttft_p99_ms": 2.0,
                "latency_p50_ms": 3.0, "latency_p99_ms": 40.0},
            1: {"n": 5, "ttft_p50_ms": 1.0, "ttft_p99_ms": 2.0,
                "latency_p50_ms": 3.0, "latency_p99_ms": 90.0},
        },
        analog={
            "raca": {"energy_pj_per_token": 123.4},
            "adc1b": {"energy_pj_per_token": 456.7},
        },
    )
    row = m.row()
    assert "class=0:n=2/p99=40ms,1:n=5/p99=90ms" in row
    assert "raca_pj_per_tok=123" in row
    assert "adc1b_pj_per_tok=457" in row


def test_preemption_rejected_on_dense(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(
            params, cfg,
            ServeConfig(kv_layout="dense", fault_injector=object()),
        )

# ---------------------------------------------------------------------------
# Self-speculative decoding (draft-k + fused verify through the paged pool)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_spec_greedy_byte_identity(arch):
    """The speculative acceptance contract: greedy decode over the mixed
    trace must be byte-identical speculate_k=4 vs plain — speculation
    changes latency, never output — for pure-attention and hybrid
    (attention + recurrent state) families."""
    cfg = get_smoke_config(arch)
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    _, out_plain = _run_layout(params, cfg, "paged")
    eng, out_spec = _run_layout(params, cfg, "paged", {"speculate_k": 4})
    assert out_plain == out_spec
    m = eng.metrics()
    assert m.spec_rounds > 0 and m.spec_drafted > 0
    # greedy drafts verify against themselves: every non-truncated draft
    # accepts, so the only losses are budget/EOS truncation mid-round
    assert m.spec_accepted <= m.spec_drafted
    assert m.spec_acceptance > 0.5


def test_spec_wta_byte_identity(smoke):
    """WTA stochastic sampling is a pure function of (slot key, step), so
    the draft run resamples the SAME votes the plain engine would have —
    stochastic streams stay byte-identical under speculation too."""
    cfg, params = smoke
    wcfg = dataclasses.replace(cfg, wta_head=True)
    _, out_plain = _run_layout(params, wcfg, "paged")
    _, out_spec = _run_layout(params, wcfg, "paged", {"speculate_k": 3})
    assert out_plain == out_spec


def test_spec_forced_rejection_mid_run(smoke):
    """Tamper with the REPORTED draft tokens (host side, after the device
    round) so the engine sees a mismatch and takes the rollback path: the
    verifier consumed the true drafts, its resample IS the plain-engine
    token, so the published stream must stay byte-identical while
    spec_rollback compiles exactly once and acceptance drops."""
    cfg, params = smoke
    _, out_plain = _run_layout(params, cfg, "paged")

    sc = ServeConfig(
        max_batch=3, max_new_tokens=8, max_len=64, kv_block_size=8,
        kv_layout="paged", speculate_k=4,
    )
    eng = ServingEngine(params, cfg, sc)
    orig = eng._spec_round
    calls = {"n": 0}

    def tampered(*a, **kw):
        cache, d, dok, v, vok, vs = orig(*a, **kw)
        calls["n"] += 1
        if calls["n"] % 2 == 0:  # every other round rejects at step 1
            d = np.asarray(d).copy()
            d[:, 1] ^= 1
        return cache, d, dok, v, vok, vs

    eng._spec_round = tampered
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    out_spec = eng.run()
    eng._spec_round = orig  # compile_counts reads the jitted entry point
    assert out_plain == out_spec
    m = eng.metrics()
    assert calls["n"] >= 2
    assert m.spec_accepted < m.spec_drafted  # rejections really happened
    assert eng.compile_counts()["spec_rollback"] == 1


@pytest.mark.parametrize("arch", ["stablelm-3b", "recurrentgemma-2b"])
def test_spec_preempt_restore_byte_identity(arch):
    """Preempting a SPECULATING slot (pages spilled between rounds, slot
    freed, restored through the admission gate) must not perturb the
    stream: rollback state, pos, and the drafted-KV dead rows all travel
    through spill/restore correctly."""
    from repro.serving import FaultInjector

    # speculation emits up to k tokens per TICK, so the trace drains in
    # far fewer ticks than the plain preempt test — inject early, and a
    # late event that finds nothing left to spill is fine (>= 1 applied)
    inj = FaultInjector().at(1, "preempt").at(3, "preempt")
    cfg, params, eng = _preempt_fixture(arch, injector=inj, speculate_k=3)
    prompts = [list(range(1, 10)), list(range(2, 14))]
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    m = eng.metrics()
    assert m.preemptions >= 1 and m.restores == m.preemptions
    assert m.spec_rounds > 0

    _, _, ref = _preempt_fixture(arch)  # plain, unpreempted oracle
    ref_rids = [ref.submit(p, 10) for p in prompts]
    ref_out = ref.run()
    for r, rr in zip(rids, ref_rids):
        assert out[r] == ref_out[rr], arch


def test_spec_sharded_1x1_mesh_byte_identity(smoke):
    """The mesh-aware speculative entry points (spec_round/spec_rollback
    from make_sharded_paged_entry_points) produce the same stream as the
    unsharded jits on a degenerate 1x1 mesh."""
    from repro.launch.mesh import make_host_mesh

    cfg, params = smoke
    _, base = _run_layout(params, cfg, "paged", {"speculate_k": 4})
    _, shard = _run_layout(
        params, cfg, "paged",
        {"speculate_k": 4, "mesh": make_host_mesh(model=1, data=1)},
    )
    assert base == shard


def test_spec_recompile_guard(smoke):
    """One spec_round compile per decode-window width (the same
    power-of-two bucketing as serve_step), zero rollback compiles on a
    fault-free greedy trace, and a second identical trace through the
    same engine compiles nothing new."""
    cfg, params = smoke
    eng, _ = _run_layout(params, cfg, "paged", {"speculate_k": 4})
    counts = eng.compile_counts()
    assert 1 <= counts["spec_round"] <= 4
    assert counts["spec_rollback"] == 0  # greedy drafts never reject
    for p, b in zip(MIXED_PROMPTS, MIXED_BUDGETS):
        eng.submit(p, b)
    eng.run()
    assert eng.compile_counts() == counts, "steady-state trace recompiled"


def test_spec_validation_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="speculate_k"):
        ServingEngine(params, cfg, ServeConfig(speculate_k=-1))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(
            params, cfg,
            ServeConfig(kv_layout="dense", speculate_k=2),
        )
    with pytest.raises(ValueError, match="max_new_tokens"):
        ServingEngine(
            params, cfg,
            ServeConfig(speculate_k=8, max_new_tokens=8),
        )


# ---------------------------------------------------------------------------
# Spill-store bytes budget (LRU drop + recompute-from-prompt restore)
# ---------------------------------------------------------------------------


def test_spill_budget_validation_is_loud(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="spill_budget_bytes"):
        ServingEngine(params, cfg, ServeConfig(spill_budget_bytes=-1))
    with pytest.raises(ValueError, match="dense"):
        ServingEngine(
            params, cfg,
            ServeConfig(kv_layout="dense", spill_budget_bytes=1 << 20),
        )


def test_spill_budget_drop_recomputes_byte_identical(smoke):
    """A zero budget drops EVERY spill record at insertion: the preempted
    victim restores through the fresh-admission gate (full prompt
    recompute + teacher-forced replay of its published tokens) and still
    finishes byte-identical to an unpreempted run."""
    from repro.serving import FaultInjector

    cfg, params = smoke
    inj = FaultInjector().at(4, "preempt").at(8, "preempt")
    _, _, eng = _preempt_fixture(
        "stablelm-3b", injector=inj, spill_budget_bytes=0
    )
    prompts = [list(range(1, 10)), list(range(2, 14))]
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    m = eng.metrics()
    assert m.preemptions == 2 and m.spill_drops == 2
    assert eng._spill == {} and eng._spill_bytes == 0

    _, _, ref = _preempt_fixture("stablelm-3b")
    ref_rids = [ref.submit(p, 10) for p in prompts]
    ref_out = ref.run()
    for r, rr in zip(rids, ref_rids):
        assert out[r] == ref_out[rr]


def test_spill_budget_keeps_newest_drops_oldest(smoke):
    """With a budget sized for ONE record, a tick that spills two victims
    keeps only the newer one: the second insertion drops the first
    (oldest — dict insertion order).  The kept victim restores from its
    host pages (counted in ``restores``); the dropped one re-admits
    through the fresh gate and replays — both streams stay byte-identical
    to an unpreempted run."""
    from repro.serving import FaultInjector

    cfg, params = smoke
    # size the budget by spying on the store at insertion time — a
    # spilled victim restores through the admission gate later in the
    # SAME tick (its slot and blocks are free again by then), so the
    # store is empty whenever tick() returns and can't be probed from
    # outside
    probe = FaultInjector().at(3, "preempt")
    _, _, peng = _preempt_fixture("stablelm-3b", injector=probe)
    sizes: list[int] = []
    orig = peng._store_spill

    def spy(rid, rec):
        orig(rid, rec)
        sizes.append(peng._spill_bytes)

    peng._store_spill = spy
    peng.submit(list(range(1, 10)), 10)
    for _ in range(4):
        peng.tick()
    assert sizes, "probe engine never spilled"
    one = sizes[0]

    # spill records are fixed-width (trash-padded page-id vectors), so
    # both victims cost exactly `one`; preempting both in one tick puts
    # the store over budget before either can restore
    inj = FaultInjector().at(3, "preempt").at(3, "preempt")
    _, _, eng = _preempt_fixture(
        "stablelm-3b", injector=inj, spill_budget_bytes=one
    )
    prompts = [list(range(1, 10)), list(range(2, 14))]
    rids = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    m = eng.metrics()
    assert m.preemptions == 2 and m.spill_drops == 1
    assert m.restores == 1  # only the kept (newest) record page-restores
    assert eng.blocks.available == eng.blocks.capacity

    _, _, ref = _preempt_fixture("stablelm-3b")
    ref_rids = [ref.submit(p, 10) for p in prompts]
    ref_out = ref.run()
    for r, rr in zip(rids, ref_rids):
        assert out[r] == ref_out[rr]


def test_spill_budget_unbounded_never_drops(smoke):
    from repro.serving import FaultInjector

    cfg, params = smoke
    inj = FaultInjector().at(4, "preempt").at(8, "preempt")
    _, _, eng = _preempt_fixture("stablelm-3b", injector=inj)
    for p in ([1, 2, 3, 4], list(range(2, 14))):
        eng.submit(p, 10)
    eng.run()
    m = eng.metrics()
    assert m.preemptions == 2 and m.spill_drops == 0
