"""Continuous-batching serving engine: scheduler lifecycle, engine
equivalence with the static reference, and the WTA vote-concentration
property (paper Fig. 6) at the serving layer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch import specs as SP
from repro.models import get_model_fns
from repro.serving import (
    RequestState,
    Scheduler,
    ServeConfig,
    ServingEngine,
    StaticServingEngine,
    left_pad,
)

# ---------------------------------------------------------------------------
# Scheduler (pure host logic, no model)
# ---------------------------------------------------------------------------


def test_fifo_admission_order():
    s = Scheduler(n_slots=2)
    rids = [s.submit([1], 4).rid for _ in range(4)]
    admitted = s.admit()
    assert [r.rid for r in admitted] == rids[:2]
    assert [r.slot for r in admitted] == [0, 1]
    assert all(r.state is RequestState.PREFILL for r in admitted)
    assert s.queued() == 2
    # no free slot -> nothing admitted
    assert s.admit() == []
    # free slot 1 -> the NEXT queued rid goes there (FIFO, not LIFO)
    admitted[1].state = RequestState.DECODE
    s.evict(admitted[1], "length")
    refill = s.admit()
    assert [r.rid for r in refill] == [rids[2]]
    assert refill[0].slot == 1


def test_slot_refill_after_eos_eviction():
    s = Scheduler(n_slots=1)
    a = s.submit([1, 2], max_new_tokens=8)
    b = s.submit([3], max_new_tokens=8)
    (req,) = s.admit()
    assert req is a
    s.start_decode(req)
    assert s.record_token(req, 5, eos_token=5) is True
    assert a.state is RequestState.DONE
    assert a.done_reason == "eos"
    assert a.output == [5]
    # the freed slot is immediately refillable by the next queued request
    (req2,) = s.admit()
    assert req2 is b and req2.slot == 0
    assert s.occupancy() == 1.0


def test_left_pad_alignment():
    assert left_pad([1, 2], 5) == [0, 0, 0, 1, 2]
    assert left_pad([1, 2, 3], 3) == [1, 2, 3]
    assert left_pad([], 2) == [0, 0]
    with pytest.raises(ValueError):
        left_pad([1, 2, 3], 2)


def test_eos_negative_never_stops_early():
    """eos_token=-1 (the default) must never match a real token id —
    including token 0, the pad id."""
    s = Scheduler(n_slots=1)
    req = s.submit([1], max_new_tokens=4)
    s.admit()
    s.start_decode(req)
    for tok in (0, -0, 7, 0):
        done = s.record_token(req, tok, eos_token=-1)
    assert done is True
    assert req.done_reason == "length"
    assert req.output == [0, 0, 7, 0]


def test_scheduler_views():
    s = Scheduler(n_slots=4)
    assert not s.has_work()
    r = s.submit([1], 2)
    assert s.has_work() and s.occupancy() == 0.0
    s.admit()
    s.start_decode(r)
    assert s.occupancy() == 0.25
    assert s.active() == [r]
    s.record_token(r, 1, eos_token=-1)
    s.record_token(r, 1, eos_token=-1)
    assert not s.has_work()
    assert s.all_requests() == [r]


# ---------------------------------------------------------------------------
# Engine (smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-3b")
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_static_vs_continuous_byte_identical(smoke):
    """With matching padded prompt windows (prompt lengths on the single
    prefill bucket boundary == the static batch max), greedy decoding must
    be byte-identical between the old static path and the scheduler."""
    cfg, params = smoke
    prompts = [
        [5, 6, 7, 1, 2, 3, 4, 9],
        [1, 2, 3],          # mixed length: both engines left-pad to 8
        [9, 8, 7, 6, 5, 4, 3, 2],
    ]
    sc = ServeConfig(
        max_batch=3, max_new_tokens=6, max_len=64, prefill_buckets=(8,)
    )
    cont = ServingEngine(params, cfg, sc)
    stat = StaticServingEngine(params, cfg, sc)
    for p in prompts:
        cont.submit(p)
        stat.submit(p)
    assert cont.step() == stat.step()


def test_mid_flight_slot_refill(smoke):
    """More requests than slots: the queue drains through freed slots and
    every request still completes with its full budget."""
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg, ServeConfig(max_batch=2, max_new_tokens=3, max_len=32)
    )
    rids = [eng.submit([3 + i, 7], max_new_tokens=3) for i in range(5)]
    outs = eng.run()
    assert sorted(outs) == rids
    assert all(len(outs[r]) == 3 for r in rids)
    m = eng.metrics()
    assert m.completed == 5
    assert m.prefills == 5
    assert 0.0 < m.occupancy_mean <= 1.0
    assert m.tokens_per_s > 0
    assert m.ttft_mean > 0


def test_engine_eos_never_stops_early(smoke):
    cfg, params = smoke
    eng = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=2, max_new_tokens=4, max_len=32, eos_token=-1),
    )
    eng.submit([5, 6, 7])
    (out,) = eng.step()
    assert len(out) == 4


def test_engine_eos_evicts_and_truncates(smoke):
    """Learn what the model emits greedily, then declare that token EOS —
    the request must stop at it and the engine must stay healthy."""
    cfg, params = smoke
    probe = ServingEngine(
        params, cfg, ServeConfig(max_batch=1, max_new_tokens=4, max_len=32)
    )
    probe.submit([5, 6, 7])
    (ref,) = probe.step()
    eos = ref[1]  # stop on the second emitted token
    eng = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=1, max_new_tokens=4, max_len=32, eos_token=eos),
    )
    eng.submit([5, 6, 7])
    eng.submit([5, 6, 7])  # refills the slot after the eviction
    outs = eng.step()
    assert len(outs) == 2
    for out in outs:
        assert out == ref[: ref.index(eos) + 1]
    done = eng.sched.all_requests()
    assert all(r.done_reason == "eos" for r in done)


def test_per_request_sampling_invariant_to_batch_composition(smoke):
    """Per-slot PRNG keys: a WTA-sampled request emits the same tokens
    whether it runs alone or alongside other requests."""
    cfg, params = smoke
    wcfg = dataclasses.replace(cfg, wta_head=True)
    sc = ServeConfig(max_batch=3, max_new_tokens=4, max_len=32, seed=11)
    solo = ServingEngine(params, wcfg, sc)
    rid_solo = solo.submit([5, 6, 7])
    out_solo = solo.run()[rid_solo]

    crowd = ServingEngine(params, wcfg, sc)
    rid = crowd.submit([5, 6, 7])  # same rid 0 -> same per-request key
    crowd.submit([1, 2, 3, 4])
    crowd.submit([9])
    out_crowd = crowd.run()[rid]
    assert out_solo == out_crowd


# ---------------------------------------------------------------------------
# WTA majority-vote concentration (paper Fig. 6 at the serving layer)
# ---------------------------------------------------------------------------


def test_wta_vote_concentration_with_trials(smoke):
    """As the trial count T grows, the majority vote concentrates on the
    argmax token — the paper's accuracy-recovery mechanism, exercised
    through the serving sampler (`sample_tokens`) with per-slot keys."""
    cfg, _ = smoke
    z = jnp.asarray(
        [0.0, -0.5, 0.3, 2.0, 0.8, -1.0, 0.5, -0.2,
         0.1, -0.8, 0.4, 0.0, -0.3, 0.6, -0.6, 0.2],
        jnp.float32,
    )
    target = int(jnp.argmax(z))
    n_samples = 256
    logits = jnp.broadcast_to(z, (n_samples, z.shape[0]))
    base = jax.random.PRNGKey(123)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n_samples)
    )
    steps = jnp.zeros((n_samples,), jnp.int32)

    rates = {}
    for trials in (1, 16, 256):
        wcfg = dataclasses.replace(
            cfg,
            wta_head=True,
            analog=dataclasses.replace(cfg.analog, wta_trials=trials),
        )
        toks = SP.sample_tokens(wcfg, logits, keys, steps)
        rates[trials] = float(jnp.mean(toks == target))
    # monotone concentration (with sampling slack) ... Fig. 6 mechanism
    assert rates[16] > rates[1] - 0.05
    assert rates[256] > rates[16] - 0.05
    assert rates[256] > 0.9, rates
    assert rates[256] > rates[1] + 0.1, rates
