"""The analog fault model and the degraded-mode serving loop.

Three layers, pinned independently:

* **backend registry + fault model** — ``make_backend`` edges, the
  exception-safe ``use_backend`` scope, the zero-knob bit-identity
  contract (``sim_faulty`` with every knob at zero is BIT-identical to
  ``sim`` per public op family), and the fault-state host API
  (deterministic stuck maps, drift clock, degrade/recover, tile
  retirement).
* **detection** — the int32 logit-sanity codes (NaN / saturation /
  entropy collapse) and the known-answer canary probe.
* **mitigation + degradation** — redundant-read majority voting, the
  DegradationPolicy ladder (speculation off -> more redundant reads ->
  load shedding) and its reversibility on canary recovery.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.analog import AnalogConfig
from repro.kernels import backend as BK
from repro.kernels import ops
from repro.models import get_model_fns
from repro.serving import (
    DegradationPolicy,
    FaultInjector,
    ServeConfig,
    ServingEngine,
)

FaultConfig = BK.FaultConfig


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-3b")
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Backend registry + use_backend scope
# ---------------------------------------------------------------------------


def test_make_backend_unknown_name_is_loud():
    with pytest.raises(ValueError, match="unknown device backend 'phys'"):
        BK.make_backend("phys")
    try:
        BK.make_backend("phys")
    except ValueError as e:
        for name in BK.BACKENDS:
            assert name in str(e)


def test_make_backend_without_model_cfg():
    """model_cfg=None: a pure-dispatch backend with zeroed shape counts —
    note_call still works, it just tallies nothing."""
    bk = BK.make_backend("sim")
    bk.note_call(
        {"prefill": 3, "decode": 2, "draft": 0, "samples": 2,
         "kv_tokens": 5, "redundant": 1}
    )
    snap = bk.snapshot(published_tokens=0)
    assert snap["tokens_computed"]["total"] == 5
    assert snap["redundant_read_events"] == 1
    assert all(v == 0 for v in snap["counts"].values())
    assert all(v == 0 for v in snap["per_redundant_counts"].values())


def test_snapshot_zero_published_tokens_no_division_crash():
    bk = BK.make_backend("sim")
    snap = bk.snapshot(published_tokens=0)
    assert snap["tokens_published"] == 0
    # per-token figures fall back to a denominator of 1, not a crash
    assert snap["raca"]["energy_pj_per_token"] == snap["raca"][
        "energy_pj_gross"
    ]


def test_use_backend_restores_on_exception():
    prev = BK.get_backend()
    faulty = BK.make_backend("sim_faulty")
    with pytest.raises(RuntimeError, match="boom"):
        with BK.use_backend(faulty):
            assert BK.get_backend() is faulty
            raise RuntimeError("boom")
    assert BK.get_backend() is prev


def test_use_backend_nests():
    prev = BK.get_backend()
    a, b = BK.make_backend("sim"), BK.make_backend("sim_faulty")
    with BK.use_backend(a):
        with BK.use_backend(b):
            assert BK.get_backend() is b
        assert BK.get_backend() is a
    assert BK.get_backend() is prev


# ---------------------------------------------------------------------------
# Zero-knob bit-identity, per public op family
# ---------------------------------------------------------------------------


def _zero_knob():
    return BK.make_backend("sim_faulty", fault=FaultConfig())


def test_zero_knob_crossbar_mac_bit_identical():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    for binarize, cfg in (
        (True, AnalogConfig(mode="analog_stochastic")),
        (False, AnalogConfig(mode="analog_linear", quantize=False)),
    ):
        ref = ops.crossbar_mac(x, w, key, cfg, binarize=binarize)
        with BK.use_backend(_zero_knob()):
            got = ops.crossbar_mac(x, w, key, cfg, binarize=binarize)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_zero_knob_wta_counts_bit_identical():
    z = jax.random.normal(jax.random.PRNGKey(4), (2, 32))
    key = jax.random.PRNGKey(5)
    ref = ops.wta_counts(z, key, n_trials=8, vth0=0.5, sigma_z=1.0)
    with BK.use_backend(_zero_knob()):
        got = ops.wta_counts(z, key, n_trials=8, vth0=0.5, sigma_z=1.0)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_zero_knob_stoch_round_bit_identical():
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 8))
    key = jax.random.PRNGKey(7)
    ref = ops.stoch_round(x, key, step=0.125, lo=-16.0, hi=15.875)
    with BK.use_backend(_zero_knob()):
        got = ops.stoch_round(x, key, step=0.125, lo=-16.0, hi=15.875)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_zero_knob_wta_readout_params_identity():
    assert _zero_knob().wta_readout_params(0.5, 1.702) == (0.5, 1.702)


def test_zero_knob_canary_passes():
    exp = ops.canary_expected()
    with BK.use_backend(_zero_knob()):
        got = np.asarray(ops.canary_mac(jax.random.PRNGKey(0)), np.float32)
    rel = float(np.max(np.abs(got - exp))) / float(np.max(np.abs(exp)))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# Fault model host API: stuck maps, drift, degrade/recover, retirement
# ---------------------------------------------------------------------------


def test_stuck_maps_deterministic_and_seed_sensitive():
    a = BK.make_backend("sim_faulty", fault=FaultConfig(stuck_rate=0.05))
    b = BK.make_backend("sim_faulty", fault=FaultConfig(stuck_rate=0.05))
    c = BK.make_backend(
        "sim_faulty", fault=FaultConfig(seed=9, stuck_rate=0.05)
    )
    sa = a._stuck_masks((128, 64))
    sb = b._stuck_masks((128, 64))
    sc = c._stuck_masks((128, 64))
    np.testing.assert_array_equal(sa[0], sb[0])
    np.testing.assert_array_equal(sa[1], sb[1])
    assert not np.array_equal(sa[0], sc[0])
    # SA0 and SA1 are disjoint, total density near the configured rate
    assert not np.any(sa[0] & sa[1])
    density = (sa[0].sum() + sa[1].sum()) / (128 * 64)
    assert 0.02 < density < 0.08


def test_drift_clock_and_version_bumps():
    bk = BK.make_backend(
        "sim_faulty", fault=FaultConfig(drift_nu=0.1, drift_quant=0.02)
    )
    assert bk.fault_state()["drift_mult"] == 1.0
    v0 = bk.fault_version
    # drive the clock until the quantized multiplier crosses a bucket
    for _ in range(200):
        bk.advance_clock(1)
    st = bk.fault_state()
    assert st["drift_mult"] < 1.0
    assert bk.fault_version > v0
    bk.recover()
    assert bk.fault_state()["drift_mult"] == 1.0
    assert bk.fault_state()["clock"] == 0


def test_degrade_rejects_unknown_knob():
    bk = BK.make_backend("sim_faulty")
    with pytest.raises(ValueError, match="unknown knob"):
        bk.degrade(stuck_rate=0.5)


def test_degrade_overrides_and_recover_clears():
    bk = BK.make_backend("sim_faulty")
    v0 = bk.fault_version
    bk.degrade(comparator_offset=0.3, read_sigma_inflation=0.5)
    assert bk.fault_version > v0
    vth0, sig = bk.wta_readout_params(0.5, 1.0)
    assert vth0 == pytest.approx(0.8) and sig == pytest.approx(1.5)
    bk.recover()
    assert bk.wta_readout_params(0.5, 1.0) == (0.5, 1.0)


def test_tile_retirement_clears_stuck_cells_and_persists():
    bk = BK.make_backend(
        "sim_faulty",
        fault=FaultConfig(stuck_rate=0.04, tile_rows=32, tile_cols=32),
    )
    bk._stuck_masks((64, 64))  # 4 tiles, each ~4% stuck
    assert bk.stuck_cell_count() > 0
    n = bk.retire_tiles(0.01)
    assert n == 4 and bk.retired_tiles == 4
    assert bk.stuck_cell_count() == 0
    # one-way: recover() resets knobs/clock but NOT physical remapping
    bk.recover()
    assert bk.retired_tiles == 4
    # idempotent: an already-retired tile is never re-counted
    assert bk.retire_tiles(0.01) == 0


def test_retire_noop_below_threshold():
    bk = BK.make_backend(
        "sim_faulty", fault=FaultConfig(stuck_rate=0.01)
    )
    bk._stuck_masks((128, 128))
    assert bk.retire_tiles(0.5) == 0
    assert bk.stuck_cell_count() > 0


def test_stuck_cells_move_the_linear_read():
    """Nonzero stuck rate must actually perturb the crossbar output (the
    zero-knob identity test above would pass vacuously otherwise)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128))
    w = jax.random.normal(jax.random.PRNGKey(2), (128, 8))
    cfg = AnalogConfig(mode="analog_linear", quantize=False)
    ref = np.asarray(ops.crossbar_mac(x, w, key, cfg, binarize=False))
    faulty = BK.make_backend(
        "sim_faulty", fault=FaultConfig(stuck_rate=0.05)
    )
    with BK.use_backend(faulty):
        got = np.asarray(ops.crossbar_mac(x, w, key, cfg, binarize=False))
    assert not np.array_equal(ref, got)


# ---------------------------------------------------------------------------
# ServeConfig validation
# ---------------------------------------------------------------------------


def test_serve_config_rejects_bad_fault_combos():
    """validate() (run at engine construction) rejects every bad fault
    combo loudly instead of letting it surface deep inside a tick."""
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(
            device_backend="sim_faulty", kv_layout="dense"
        ).validate()
    with pytest.raises(ValueError, match="device_fault_config"):
        ServeConfig(device_fault_config=FaultConfig()).validate()
    with pytest.raises(ValueError, match="n_redundant_reads"):
        ServeConfig(n_redundant_reads=0).validate()
    with pytest.raises(ValueError, match="canary_threshold"):
        ServeConfig(canary_threshold=0.0).validate()
    with pytest.raises(ValueError, match="tile_retire_threshold"):
        ServeConfig(tile_retire_threshold=1.5).validate()
    with pytest.raises(ValueError, match="trip_after"):
        ServeConfig(
            degradation=DegradationPolicy(trip_after=0)
        ).validate()


# ---------------------------------------------------------------------------
# Detection: sanity codes + canary in the serving engine
# ---------------------------------------------------------------------------


def _serve_cfg(**kw):
    base = dict(
        max_batch=2, max_new_tokens=6, max_len=64, kv_block_size=8,
        prefill_buckets=(16,),
    )
    base.update(kw)
    return ServeConfig(**base)


def test_zero_knob_served_stream_bit_identical(smoke):
    """The end-to-end pin behind the bench's zero_fault section: a served
    WTA trace through sim_faulty with all knobs zero matches sim."""
    cfg, params = smoke
    wcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    outs = {}
    for name in ("sim", "sim_faulty"):
        eng = ServingEngine(
            params, wcfg, _serve_cfg(device_backend=name)
        )
        for i in range(3):
            eng.submit(list(range(1 + i, 9 + i)), 5)
        outs[name] = eng.run()
    assert outs["sim"] == outs["sim_faulty"]


def test_canary_detects_comparator_offset_and_counts(smoke):
    cfg, params = smoke
    inj = FaultInjector().at(2, "degrade_device", comparator_offset=3.0)
    eng = ServingEngine(
        params, cfg,
        _serve_cfg(
            device_backend="sim_faulty", canary_interval=1,
            fault_injector=inj,
        ),
    )
    eng.submit(list(range(1, 9)), 6)
    eng.run()
    m = eng.metrics()
    assert m.canary_probes > 0
    assert 0 < m.canary_failures < m.canary_probes  # clean before tick 2
    assert m.degraded_mode == 0  # no policy armed: detection only


def test_sanity_codes_classify_saturation_and_nan():
    """The serve-step sanity vector types the failure: NaN beats
    saturation, saturation beats entropy collapse, 0 is healthy."""
    import repro.launch.specs as SP

    logits = jnp.stack(
        [
            jnp.zeros((8,)),
            jnp.full((8,), jnp.nan),
            jnp.full((8,), 1e9),
        ]
    )
    finite = jnp.all(jnp.isfinite(logits), axis=-1)
    sat = jnp.max(jnp.abs(logits), axis=-1) > 1e6
    sane = jnp.where(
        finite,
        jnp.where(sat, SP.SANE_SATURATED, SP.SANE_OK),
        SP.SANE_NAN,
    )
    assert list(np.asarray(sane)) == [
        SP.SANE_OK, SP.SANE_NAN, SP.SANE_SATURATED
    ]
    assert SP.SANITY_REASONS[SP.SANE_NAN] == "nan"
    assert SP.SANITY_REASONS[SP.SANE_SATURATED] == "saturated"
    assert SP.SANITY_REASONS[SP.SANE_ENTROPY_COLLAPSE] == "entropy_collapse"


# ---------------------------------------------------------------------------
# Mitigation + graceful degradation in the engine
# ---------------------------------------------------------------------------


def test_redundant_majority_vote_is_a_valid_stream(smoke):
    """n_redundant_reads=3: every published token is a valid id and the
    backend tallies exactly (R-1) redundant events per decode sample."""
    cfg, params = smoke
    wcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    eng = ServingEngine(params, wcfg, _serve_cfg(n_redundant_reads=3))
    eng.submit(list(range(1, 9)), 5)
    outs = eng.run()
    toks = next(iter(outs.values()))
    assert len(toks) == 5
    assert all(0 <= t < wcfg.vocab for t in toks)
    m = eng.metrics()
    assert m.redundant_read_events == 2 * m.decode_steps


def test_degradation_ladder_trips_and_recovers(smoke):
    """The full loop on one engine: injected comparator offset -> canary
    failures walk the ladder up (speculation off, redundant reads up,
    shedding); recovery walks it back to 0 — transitions recorded."""
    cfg, params = smoke
    wcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    inj = (
        FaultInjector()
        .at(2, "degrade_device", comparator_offset=3.0)
        .at(12, "recover_device")
    )
    eng = ServingEngine(
        params, wcfg,
        _serve_cfg(
            device_backend="sim_faulty",
            canary_interval=1,
            degradation=DegradationPolicy(trip_after=2, recover_after=2),
            fault_injector=inj,
            max_new_tokens=10,
        ),
    )
    eng.submit(list(range(1, 9)), 10)
    eng.run()
    # idle-tick until the canary walks the ladder back down
    for _ in range(32):
        if eng.metrics().degraded_mode == 0:
            break
        eng.tick()
    m = eng.metrics()
    assert m.canary_failures > 0
    assert m.degraded_mode == 0
    levels = [t["to"] for t in m.degraded_transitions]
    assert max(levels) >= 2  # redundant-read rung reached
    assert levels[-1] == 0
    whys = {t["why"] for t in m.degraded_transitions}
    assert whys == {"fault_pressure", "canary_recovered"}
    # the raised redundancy actually produced priced re-reads
    assert m.redundant_read_events > 0


def test_degradation_disables_speculation(smoke):
    """Rung 1: a speculating engine under persistent canary failure stops
    drafting (spec_rounds freezes) but keeps decoding to completion."""
    cfg, params = smoke
    inj = FaultInjector().at(0, "degrade_device", comparator_offset=3.0)
    eng = ServingEngine(
        params, cfg,
        _serve_cfg(
            device_backend="sim_faulty",
            canary_interval=1,
            degradation=DegradationPolicy(trip_after=1),
            fault_injector=inj,
            speculate_k=2,
            max_new_tokens=12,
        ),
    )
    rid = eng.submit(list(range(1, 9)), 12)
    eng.run()
    m = eng.metrics()
    req = eng.sched.request(rid)
    assert req.done_reason == "length" and len(req.output) == 12
    assert m.degraded_mode >= 1
    # the policy escalates at end-of-tick, so the first decode tick may
    # legitimately draft once — but the ladder trips there and spec
    # freezes (12 tokens at k=2 would take ~5 healthy rounds)
    assert m.spec_rounds <= 1


def test_shedding_holds_batch_admissions_until_recovery(smoke):
    """Rung 3 sheds priority>0 admissions; interactive traffic still
    admits.  After recover_device the queued batch request completes."""
    cfg, params = smoke
    inj = (
        FaultInjector()
        .at(0, "degrade_device", comparator_offset=3.0)
        .at(8, "recover_device")
    )
    eng = ServingEngine(
        params, cfg,
        _serve_cfg(
            device_backend="sim_faulty",
            canary_interval=1,
            degradation=DegradationPolicy(trip_after=1, recover_after=1),
            fault_injector=inj,
        ),
    )
    # ladder reaches 3 by tick 3 (trip_after=1); submit afterwards
    for _ in range(4):
        eng.tick()
    assert eng.metrics().degraded_mode == 3
    from repro.serving import PRIORITY_BATCH, PRIORITY_INTERACTIVE, \
        RequestState

    rb = eng.submit(list(range(1, 7)), 3, priority=PRIORITY_BATCH)
    ri = eng.submit(list(range(11, 17)), 3, priority=PRIORITY_INTERACTIVE)
    eng.tick()
    assert eng.sched.request(rb).state is RequestState.QUEUED  # shed
    assert eng.sched.request(ri).state is not RequestState.QUEUED
    eng.run()
    for rid in (rb, ri):
        req = eng.sched.request(rid)
        assert req.done_reason == "length" and len(req.output) == 3
