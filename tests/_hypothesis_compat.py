"""`hypothesis` if installed, else a tiny deterministic fallback sampler.

Tier-1 must collect and run on a bare container without the `hypothesis`
wheel.  When the real library is present we re-export it untouched; when it
is missing we provide just the surface the suite uses — ``given`` /
``settings`` decorators and the ``integers`` / ``floats`` / ``sampled_from``
strategies — drawing examples from a ``random.Random`` seeded by the test's
qualified name, so every run of the fallback explores the same examples
(reproducible failures, no flake).

Usage in test modules:

    from _hypothesis_compat import hypothesis, st
"""

from __future__ import annotations

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # fallback sampler
    import functools
    import inspect
    import random
    import types

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    def _integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))

    st = types.SimpleNamespace(
        integers=_integers, floats=_floats, sampled_from=_sampled_from
    )

    def _settings(deadline=None, max_examples: int = 10, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def _given(*pos_strategies, **kw_strategies):
        def deco(fn):
            # like real hypothesis, positional strategies fill the RIGHTMOST
            # parameters (the leftmost ones may be pytest fixtures)
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            pos_names = (
                names[len(names) - len(pos_strategies):]
                if pos_strategies else []
            )
            strategies = dict(zip(pos_names, pos_strategies), **kw_strategies)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_fallback_max_examples", 10)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {
                        k: s.example(rng) for k, s in strategies.items()
                    }
                    fn(*args, **kwargs, **drawn)

            # hide strategy-filled params from pytest's fixture resolution:
            # wraps() copies __wrapped__, making inspect.signature report the
            # original params, which pytest would then request as fixtures
            del wrapper.__wrapped__
            params = [
                p for p in sig.parameters.values()
                if p.name not in strategies
            ]
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper

        return deco

    hypothesis = types.SimpleNamespace(
        given=_given, settings=_settings, strategies=st
    )

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
