"""End-to-end behaviour of the paper's system (RACA on the FCNN).

The headline claims validated here (container-scale versions of
EXPERIMENTS.md §Reproduction):
  * stochastic inference accuracy RISES with the number of WTA votes and
    approaches the digital baseline (Fig. 6 trend),
  * the calibrated threshold (V_th0 > 0) beats θ=0 at low vote counts
    (Fig. 6(b) trend),
  * the full pipeline — analog crossbar MAC, thermal noise, comparator
    neurons, WTA classifier — trains and infers without any explicit
    sigmoid/softmax computation in the deploy path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.fcnn_mnist import CONFIG as FCNN_CFG
from repro.core import wta
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.data import mnist_batch, mnist_dataset
from repro.models.fcnn import fcnn_predict_digital, fcnn_predict_raca
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def trained_fcnn():
    """Train a reduced FCNN [784, 128, 64, 10] on the surrogate (the SBNN
    recipe: expectation forward — config default — hard samples at deploy)."""
    cfg = dataclasses.replace(
        FCNN_CFG,
        fcnn_layers=(784, 128, 64, 10),
        analog=dataclasses.replace(
            FCNN_CFG.analog,
            device=calibrate_v_read(DeviceParams(), 784),
            use_pallas="off",
        ),
    )
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=5e-3, state_dtype="float32",
                        stochastic_rounding=False)
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    for i in range(500):
        state, m = step(state, mnist_batch(batch=128, step=i))
    return cfg, state.params


def test_training_reached_usable_accuracy(trained_fcnn):
    cfg, params = trained_fcnn
    test = mnist_dataset(512)
    pred = fcnn_predict_digital(params, test["image"], cfg)
    acc = float((pred == test["label"]).mean())
    assert acc > 0.85, acc


def test_votes_improve_accuracy_toward_digital(trained_fcnn):
    """Fig. 6: accuracy increases with repeated stochastic inference and
    approaches the digital ceiling."""
    cfg, params = trained_fcnn
    test = mnist_dataset(256)
    digital = float(
        (fcnn_predict_digital(params, test["image"], cfg)
         == test["label"]).mean()
    )
    accs = {}
    for votes in (1, 8, 64):
        pred = fcnn_predict_raca(
            params, test["image"], cfg, jax.random.PRNGKey(7), votes
        )
        accs[votes] = float((pred == test["label"]).mean())
    assert accs[64] >= accs[1]
    assert accs[64] >= digital - 0.05, (accs, digital)


def test_threshold_zero_vs_calibrated(trained_fcnn):
    """Fig. 6(b): θ=0 approximates softmax worse; calibrated θ should be at
    least as good at moderate vote counts."""
    cfg, params = trained_fcnn
    test = mnist_dataset(256)
    k = jax.random.PRNGKey(9)
    acc_cal = float(
        (fcnn_predict_raca(params, test["image"], cfg, k, 16)
         == test["label"]).mean()
    )
    acc_zero = float(
        (fcnn_predict_raca(params, test["image"], cfg, k, 16, vth0=0.0)
         == test["label"]).mean()
    )
    assert acc_cal >= acc_zero - 0.03, (acc_cal, acc_zero)


def test_deploy_path_contains_no_softmax(trained_fcnn):
    """The RACA readout uses only comparisons + counters on top of the
    crossbar MAC — the WTA head's HLO must be exp-free."""
    cfg, params = trained_fcnn
    x = mnist_dataset(8)["image"]
    wta_hlo = jax.jit(
        lambda z, k: wta.wta_trials(
            k, z, 8, wta.calibrated_threshold()
        ).counts
    ).lower(jnp.zeros((8, 10)), jax.random.PRNGKey(0)).as_text()
    assert "exponential" not in wta_hlo
    pred = fcnn_predict_raca(params, x, cfg, jax.random.PRNGKey(3), 8)
    assert pred.shape == (8,)
