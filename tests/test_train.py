"""Training substrate: optimizer, checkpointing, fault tolerance,
stragglers, compression."""

import dataclasses
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.configs import get_smoke_config
from repro.configs.fcnn_mnist import smoke_config as fcnn_smoke
from repro.data import lm_batch, mnist_batch
from repro.models import get_model_fns
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.loop import LoopConfig, StragglerMonitor, run


def _mk(arch="stablelm-3b", **tkw):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=1e-2, state_dtype="float32",
                        stochastic_rounding=False),
        **tkw,
    )
    return cfg, tcfg


def test_loss_decreases_lm():
    cfg, tcfg = _mk()
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    losses = []
    for i in range(20):
        batch = lm_batch(cfg, batch=8, seq=16, step=i)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatched_matches_full_batch_loss_scale():
    cfg, t1 = _mk(microbatches=1)
    _, t4 = _mk(microbatches=4)
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, t1)
    s4 = init_train_state(jax.random.PRNGKey(0), cfg, t4)
    batch = lm_batch(cfg, batch=8, seq=16, step=0)
    s1b, m1 = make_train_step(cfg, t1)(s1, batch)
    s4b, m4 = make_train_step(cfg, t4)(s4, batch)
    # same data, same init: losses close; grads differ only by micro-order
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.05
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1b.params, s4b.params,
    )
    assert max(jax.tree.leaves(d)) < 5e-2


def test_adamw_bf16_states_with_stochastic_rounding_track_f32():
    p = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    cfg32 = AdamWConfig(lr=1e-2, state_dtype="float32",
                        stochastic_rounding=False, weight_decay=0.0)
    cfg16 = AdamWConfig(lr=1e-2, state_dtype="bfloat16",
                        stochastic_rounding=True, weight_decay=0.0)
    s32, s16 = adamw_init(p, cfg32), adamw_init(p, cfg16)
    p32 = p16 = p
    for i in range(30):
        g = {
            "w": jax.random.normal(jax.random.PRNGKey(100 + i), (64, 64))
            * 0.1
        }
        p32, s32, _ = adamw_update(cfg32, p32, g, s32)
        p16, s16, _ = adamw_update(
            cfg16, p16, g, s16, rng=jax.random.PRNGKey(i)
        )
    rel = float(
        jnp.linalg.norm(p32["w"] - p16["w"]) / jnp.linalg.norm(p32["w"])
    )
    assert rel < 0.05, rel


def test_checkpoint_roundtrip(tmp_path):
    cfg, tcfg = _mk()
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    like = jax.eval_shape(lambda: state)
    restored = load_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_injection_recovers_to_identical_state(tmp_path):
    """A mid-run fault + restart-from-checkpoint must produce the exact
    same final state as an uninterrupted run (stateless data pipeline)."""
    cfg, tcfg = _mk()
    batch_fn = lambda step: lm_batch(cfg, batch=4, seq=16, step=step)

    clean_dir = tmp_path / "clean"
    lcfg = LoopConfig(steps=12, ckpt_dir=str(clean_dir), ckpt_every=4,
                      log_every=100)
    state_clean, _ = run(cfg, tcfg, lcfg, batch_fn)

    faulty_dir = tmp_path / "faulty"
    lcfg2 = LoopConfig(steps=12, ckpt_dir=str(faulty_dir), ckpt_every=4,
                       log_every=100, fault_inject_step=9)
    state_faulty, stats = run(cfg, tcfg, lcfg2, batch_fn)
    assert stats["restarts"] == 1
    assert int(state_faulty.step) == 12
    for a, b in zip(
        jax.tree.leaves(state_clean.params),
        jax.tree.leaves(state_faulty.params),
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-6, rtol=1e-5,
        )


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=2.0)
    flagged = 0
    for i in range(10):
        flagged += mon.observe(0.1)
    assert flagged == 0
    assert mon.observe(0.5) is True  # 5x EMA -> straggler


def test_grad_compression_error_feedback_converges():
    """int8-compressed training stays close to uncompressed (error feedback
    carries the residual)."""
    cfg, t_plain = _mk()
    _, t_comp = _mk(compress_grads=True)
    sp = init_train_state(jax.random.PRNGKey(0), cfg, t_plain)
    sc = init_train_state(jax.random.PRNGKey(0), cfg, t_comp)
    step_p = jax.jit(make_train_step(cfg, t_plain), donate_argnums=(0,))
    step_c = jax.jit(make_train_step(cfg, t_comp), donate_argnums=(0,))
    lp, lc = [], []
    for i in range(15):
        b = lm_batch(cfg, batch=8, seq=16, step=i)
        sp, mp = step_p(sp, b)
        sc, mc = step_c(sc, b)
        lp.append(float(mp["loss"]))
        lc.append(float(mc["loss"]))
    # both decrease, and trajectories stay close
    assert np.mean(lc[-3:]) < lc[0]
    assert abs(np.mean(lc[-3:]) - np.mean(lp[-3:])) < 0.25


def test_fcnn_raca_training_works():
    """The paper's own model: stochastic-binary training decreases loss."""
    cfg = fcnn_smoke()
    cfg = dataclasses.replace(cfg, fcnn_layers=(64, 32, 16, 10))
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=5e-3, state_dtype="float32",
                        stochastic_rounding=False)
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    losses = []
    for i in range(60):
        b = mnist_batch(batch=64, step=i)
        b = {"image": b["image"][:, :64], "label": b["label"]}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3] + losses[-3:]
