"""Binary stochastic Sigmoid neurons (paper §III-A, Fig. 4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crossbar, neurons, physics

DP = physics.calibrate_v_read(physics.DeviceParams(), n_rows=784)


def test_fire_probability_matches_logistic_within_probit_bound():
    """Eq. 13: after SNR calibration the comparator matches the logistic
    within the 1.702-approximation bound (|err| < 0.0095) plus a small
    column-ΣG variation term."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (784, 64)) * 0.05
    x = (jax.random.uniform(jax.random.PRNGKey(1), (64, 784)) < 0.3).astype(
        jnp.float32
    )
    m = crossbar.map_weights(w, DP)
    z = x @ m.w_eff
    p = neurons.fire_probability_physical(
        z, crossbar.column_sum_g(m), DP
    )
    err = np.abs(np.asarray(p) - np.asarray(jax.nn.sigmoid(z)))
    assert err.max() < 0.012


def test_comparator_samples_match_fire_probability():
    """The literal circuit (sample currents, compare) is distributionally
    identical to the STE path's probability."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (256, 8)) * 0.1
    dp = physics.calibrate_v_read(physics.DeviceParams(), 256)
    x = (jax.random.uniform(jax.random.PRNGKey(3), (4, 256)) < 0.4).astype(
        jnp.float32
    )
    n = 3000
    keys = jax.random.split(jax.random.PRNGKey(4), n)
    samp = jnp.stack(
        [neurons.comparator_sample(k, x, w, dp) for k in keys[:n]]
    ).mean(0)
    m = crossbar.map_weights(w, dp)
    p = neurons.fire_probability_physical(
        x @ m.w_eff, crossbar.column_sum_g(m), dp
    )
    # MC error ~ 3·sqrt(p(1-p)/n) <= 3*0.5/sqrt(n) ≈ 0.027
    assert np.abs(np.asarray(samp) - np.asarray(p)).max() < 0.04


def test_ste_gradient_is_sigmoid_derivative():
    """STE: d/dz E[stochastic_binarize(sigmoid(z))] == sigmoid'(z)."""
    z = jnp.linspace(-3, 3, 31)

    def f(z):
        p = jax.nn.sigmoid(z)
        y = neurons.stochastic_binarize(jax.random.PRNGKey(0), p)
        return y.sum()

    g = jax.grad(f)(z)
    expected = jax.nn.sigmoid(z) * (1 - jax.nn.sigmoid(z))
    np.testing.assert_allclose(np.asarray(g), np.asarray(expected), rtol=1e-5)


def test_binarize_outputs_binary_and_unbiased():
    p = jax.random.uniform(jax.random.PRNGKey(5), (2000,))
    y = neurons.stochastic_binarize(jax.random.PRNGKey(6), p)
    assert set(np.unique(np.asarray(y))) <= {0.0, 1.0}
    keys = jax.random.split(jax.random.PRNGKey(7), 500)
    ys = jnp.stack([neurons.stochastic_binarize(k, p) for k in keys]).mean(0)
    assert np.abs(np.asarray(ys) - np.asarray(p)).max() < 0.09


def test_soft_mode_returns_probability():
    p = jnp.asarray([0.2, 0.8])
    y = neurons.stochastic_binarize(jax.random.PRNGKey(0), p, False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(p))
