"""WTA binary stochastic SoftMax neurons (paper §III-B, Fig. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import hypothesis, st

from repro.core import wta


def test_single_winner_per_trial():
    """Fig. 5(a): at most one neuron is activated per decision trial."""
    z = jax.random.normal(jax.random.PRNGKey(0), (10,))
    res = wta.wta_trials(
        jax.random.PRNGKey(1), z, n_trials=500,
        vth0=wta.calibrated_threshold(),
    )
    assert float(res.counts.sum()) == float(res.n_decisions)
    assert float(res.n_decisions) <= 500


def test_wta_approximates_softmax():
    """Eq. 14 / Fig. 5(d): cumulative vote distribution ≈ SoftMax."""
    z = jnp.asarray([1.5, 0.3, -0.5, 0.9, -1.2, 0.0, 2.0, -0.3, 0.5, 1.0])
    res = wta.wta_trials(
        jax.random.PRNGKey(2), z, n_trials=40_000,
        vth0=wta.calibrated_threshold(),
    )
    sm = jax.nn.softmax(z)
    tv = 0.5 * float(jnp.abs(res.probs - sm).sum())
    assert tv < 0.08
    assert int(jnp.argmax(res.probs)) == int(jnp.argmax(sm))


def test_expected_probs_analytic_matches_simulation():
    z = jax.random.normal(jax.random.PRNGKey(3), (6,))
    theta = wta.calibrated_threshold()
    res = wta.wta_trials(jax.random.PRNGKey(4), z, 40_000, theta)
    ana = wta.wta_expected_probs(z, theta)
    assert 0.5 * float(jnp.abs(res.probs - ana).sum()) < 0.05


def test_threshold_tradeoff():
    """§IV-C: small V_th0 degrades the SoftMax approximation (at realistic
    logit spreads — the Gaussian-tail regime); large V_th0 lowers activation
    probability (longer decision time)."""
    z = jnp.asarray([2.0, 0.4, -1.2, 0.8, -2.0, 0.0, 2.8, -0.4])
    sm = jax.nn.softmax(z)
    theta_cal = wta.calibrated_threshold()
    tvs, rates = {}, {}
    for name, theta in [("zero", 0.0), ("cal", theta_cal),
                        ("high", 2.5 * theta_cal)]:
        res = wta.wta_trials(jax.random.PRNGKey(5), z, 30_000, theta)
        tvs[name] = 0.5 * float(jnp.abs(res.probs - sm).sum())
        rates[name] = float(res.n_decisions) / 30_000
    assert tvs["cal"] < tvs["zero"]          # calibrated beats θ=0
    assert rates["high"] < rates["cal"] < rates["zero"]  # decision time ↑


def test_wta_classify_matches_argmax_for_clear_margins():
    z = jnp.zeros((8, 10)).at[jnp.arange(8), jnp.arange(8)].set(4.0)
    pred = wta.wta_classify(
        jax.random.PRNGKey(6), z, 200, wta.calibrated_threshold()
    )
    np.testing.assert_array_equal(np.asarray(pred), np.arange(8))


@hypothesis.given(
    k=st.integers(1, 4),
    c=st.integers(5, 12),
    seed=st.integers(0, 1000),
)
@hypothesis.settings(deadline=None, max_examples=20)
def test_wta_topk_valid(k, c, seed):
    """k-WTA (MoE router) always returns k distinct valid experts."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (3, c))
    share, idx = wta.wta_topk(
        jax.random.PRNGKey(seed + 1), z, k, 64, wta.calibrated_threshold()
    )
    assert idx.shape == (3, k)
    assert share.shape == (3, k)
    a = np.asarray(idx)
    assert ((a >= 0) & (a < c)).all()
    for row in a:
        assert len(set(row.tolist())) == k
