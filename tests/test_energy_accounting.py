"""Energy-count invariance: the Sim backend's analog-event tallies are
exact functions of (tokens computed x model shape), independent of HOW the
engine scheduled that work — batch composition, prefix sharing, mesh
sharding.  Speculation is the documented exception: rejected drafts burn
energy without publishing, so gross counts GROW while the published stream
stays byte-identical (the relationship, not equality, is what's pinned).
"""

import dataclasses

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import cost_model as CM
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke_config("stablelm-3b")
    params = get_model_fns(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


PROMPTS = [
    [5, 6, 7, 1, 2, 3, 4, 9],
    [1, 2, 3],
    [9, 8, 7, 6, 5],
    [4, 4, 4, 4, 4, 4],
]


def _serve(cfg, params, prompts, arrivals, **kw):
    """Drive ``prompts`` with per-request arrival ticks; return metrics."""
    sc = ServeConfig(
        max_batch=2, max_new_tokens=4, max_len=64, kv_block_size=8, **kw
    )
    eng = ServingEngine(params, cfg, sc)
    order = sorted(range(len(prompts)), key=lambda i: arrivals[i])
    i = tick = 0
    while i < len(order) or eng.sched.has_work():
        while i < len(order) and arrivals[order[i]] <= tick:
            eng.submit(prompts[order[i]])
            i += 1
        eng.tick()
        tick += 1
    return eng.metrics()


def test_counts_invariant_to_batch_composition(smoke):
    """The same request set through different arrival patterns (burst vs
    trickle, so slot co-residency differs tick by tick) must account
    BITWISE-identical analog event totals: idle-slot padding is never
    logical work."""
    cfg, params = smoke
    burst = _serve(cfg, params, PROMPTS, [0, 0, 0, 0])
    trickle = _serve(cfg, params, PROMPTS, [0, 3, 6, 9])
    assert burst.analog["counts"] == trickle.analog["counts"]
    assert (
        burst.analog["tokens_computed"]
        == trickle.analog["tokens_computed"]
    )
    assert burst.analog["sample_events"] == trickle.analog["sample_events"]
    # and the totals reconcile exactly against the per-event shape counts
    a = burst.analog
    expected = (
        CM.AnalogOpCounts.from_dict(a["per_token_counts"])
        .scaled(a["tokens_computed"]["total"])
        + CM.AnalogOpCounts.from_dict(a["per_sample_counts"])
        .scaled(a["sample_events"])
        + CM.AnalogOpCounts.from_dict(a["per_kv_token_counts"])
        .scaled(a["kv_written_tokens"])
        + CM.AnalogOpCounts.from_dict(a["per_redundant_counts"])
        .scaled(a["redundant_read_events"])
    )
    assert expected.as_dict() == a["counts"]
    assert a["redundant_read_events"] == 0  # no redundancy configured


def test_counts_invariant_to_prefix_sharing_flag(smoke):
    """Distinct prompts share nothing, so the sharing machinery must be
    accounting-neutral: identical tallies with the flag on and off."""
    cfg, params = smoke
    on = _serve(cfg, params, PROMPTS, [0, 1, 2, 3],
                enable_prefix_sharing=True)
    off = _serve(cfg, params, PROMPTS, [0, 1, 2, 3],
                 enable_prefix_sharing=False)
    assert on.analog["counts"] == off.analog["counts"]
    assert on.analog["tokens_computed"] == off.analog["tokens_computed"]


def test_sharing_hits_account_only_computed_tokens(smoke):
    """Repeated prompts with sharing ON skip prefill compute — the energy
    tally drops by EXACTLY the skipped tokens: computed + saved (sharing
    on) == computed (sharing off), published streams equal."""
    cfg, params = smoke
    prompts = [[7, 7, 7, 1, 2, 3, 4, 5]] * 3  # identical: full-hit repeats
    on = _serve(cfg, params, prompts, [0, 2, 4],
                enable_prefix_sharing=True)
    off = _serve(cfg, params, prompts, [0, 2, 4],
                 enable_prefix_sharing=False)
    assert on.total_tokens == off.total_tokens
    tc_on, tc_off = on.analog["tokens_computed"], off.analog["tokens_computed"]
    assert on.prefix_hits > 0 and on.prefill_tokens_saved > 0
    assert (
        tc_on["prefill"] + on.prefill_tokens_saved == tc_off["prefill"]
    )
    assert tc_on["decode"] == tc_off["decode"]
    # strictly fewer accounted events with sharing on — energy follows
    assert (
        on.analog["raca"]["energy_pj_gross"]
        < off.analog["raca"]["energy_pj_gross"]
    )


def test_counts_invariant_to_1x1_mesh(smoke):
    """A 1x1 mesh is byte-identical compute, so it must be tally-identical
    accounting too."""
    from repro.launch.mesh import make_host_mesh

    cfg, params = smoke
    plain = _serve(cfg, params, PROMPTS[:2], [0, 1])
    meshed = _serve(
        cfg, params, PROMPTS[:2], [0, 1],
        mesh=make_host_mesh(model=1, data=1),
    )
    assert plain.analog["counts"] == meshed.analog["counts"]
    assert (
        plain.analog["tokens_computed"]
        == meshed.analog["tokens_computed"]
    )


def test_speculative_gross_vs_published_relationship(smoke):
    """speculate_k=2 vs plain decode at equal published streams: gross
    counts are NOT equal — every round forwards k drafted + k verify
    positions whether or not they publish.  Pin the documented
    relationship instead of equality."""
    cfg, params = smoke
    k = 2
    plain = _serve(cfg, params, PROMPTS[:3], [0, 0, 0], speculate_k=0)
    spec = _serve(cfg, params, PROMPTS[:3], [0, 0, 0], speculate_k=k)
    # same accepted-token streams → same published totals
    assert spec.total_tokens == plain.total_tokens
    assert spec.analog["tokens_published"] == plain.analog[
        "tokens_published"
    ]
    tc = spec.analog["tokens_computed"]
    # drafts happened, in whole k-deep rounds, with a matching verify
    # re-decode per drafted token (plain-tick fallbacks may add more
    # decode, never less)
    assert tc["draft"] > 0 and tc["draft"] % k == 0
    assert tc["decode"] >= tc["draft"]
    assert plain.analog["tokens_computed"]["draft"] == 0
    # prefill work is arrival-pattern/shape work, identical across modes
    assert tc["prefill"] == plain.analog["tokens_computed"]["prefill"]
    # gross energy strictly grows: rejected drafts burn energy silently,
    # published-token energy can only be worse than plain decode
    assert (
        spec.analog["raca"]["energy_pj_gross"]
        > plain.analog["raca"]["energy_pj_gross"]
    )
    assert (
        spec.analog["raca"]["energy_pj_per_token"]
        > plain.analog["raca"]["energy_pj_per_token"]
    )


def test_int8_and_wta_add_their_event_classes(smoke):
    """Feature knobs add exactly their own event class: int8 KV adds
    stochastic-rounding events, the WTA head adds comparator votes; the
    crossbar/tile/DAC base counts stay bitwise-identical."""
    cfg, params = smoke
    base = _serve(cfg, params, PROMPTS[:2], [0, 0])
    i8cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    i8 = _serve(i8cfg, params, PROMPTS[:2], [0, 0])
    b, q = base.analog, i8.analog
    assert b["counts"]["stoch_round_events"] == 0
    assert q["counts"]["stoch_round_events"] == (
        q["kv_written_tokens"]
        * q["per_kv_token_counts"]["stoch_round_events"]
    ) and q["counts"]["stoch_round_events"] > 0
    for key in ("macs", "tile_reads", "dac_conversions"):
        assert b["counts"][key] == q["counts"][key]
    wcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    w = _serve(wcfg, params, PROMPTS[:2], [0, 0]).analog
    assert w["counts"]["comparator_decisions"] == (
        b["counts"]["comparator_decisions"]
        + w["sample_events"] * 8 * cfg.vocab
    )


def test_redundant_reads_priced_integer_exactly(smoke):
    """``n_redundant_reads=R`` re-runs the comparator readout R-1 extra
    times per decode sample (majority vote): the ledger must record those
    events and price them as exactly ``wta_trials * vocab`` extra
    comparator decisions each — reconciled integer-exactly, with the
    published sample count unchanged."""
    cfg, params = smoke
    wcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    one = _serve(wcfg, params, PROMPTS[:2], [0, 0], n_redundant_reads=1)
    three = _serve(wcfg, params, PROMPTS[:2], [0, 0], n_redundant_reads=3)
    a1, a3 = one.analog, three.analog
    assert a1["redundant_read_events"] == 0
    assert a3["redundant_read_events"] > 0
    # redundancy is pure re-reading: the logical workload is unchanged
    assert a3["tokens_computed"] == a1["tokens_computed"]
    assert a3["sample_events"] == a1["sample_events"]
    # each redundant read is one extra full WTA readout, nothing else
    assert a3["per_redundant_counts"]["comparator_decisions"] == (
        8 * wcfg.vocab
    )
    assert a3["counts"]["comparator_decisions"] == (
        a1["counts"]["comparator_decisions"]
        + a3["redundant_read_events"] * 8 * wcfg.vocab
    )
    assert a3["counts"]["wta_samples"] == a1["counts"]["wta_samples"]
    # and the generic ledger reconciliation closes with the new term
    expected = (
        CM.AnalogOpCounts.from_dict(a3["per_token_counts"])
        .scaled(a3["tokens_computed"]["total"])
        + CM.AnalogOpCounts.from_dict(a3["per_sample_counts"])
        .scaled(a3["sample_events"])
        + CM.AnalogOpCounts.from_dict(a3["per_kv_token_counts"])
        .scaled(a3["kv_written_tokens"])
        + CM.AnalogOpCounts.from_dict(a3["per_redundant_counts"])
        .scaled(a3["redundant_read_events"])
    )
    assert expected.as_dict() == a3["counts"]
    # priced: gross energy strictly grows with the extra reads
    assert (
        a3["raca"]["energy_pj_gross"] > a1["raca"]["energy_pj_gross"]
    )
