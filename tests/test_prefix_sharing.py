"""Property-based suite for the refcounted, prefix-indexed BlockAllocator.

The allocator is pure host logic, so this file fuzzes it hard: random
admission / COW-fork / eviction traces with invariants re-checked after
EVERY operation.  Sharing multiplies aliasing hazards (refcounts, fork
accounting, index staleness); the invariants below are the full safety
contract the serving engine relies on:

  * the free list and the allocated (refcount >= 1) pages partition the
    non-reserved pool — no page is ever both, none is lost, and the pool
    never exceeds its ``n_blocks`` budget;
  * refcounts are conserved: a page's refcount equals the number of
    (owner, mapped-or-spare) references that exist to it;
  * no block is ever double-freed (the free list stays duplicate-free and
    releasing an unknown owner raises);
  * the prefix index only ever maps content hashes to LIVE pages, and a
    page carries at most one hash.

Randomness goes through tests/_hypothesis_compat (real hypothesis when
installed, the deterministic fallback sampler otherwise), so the fuzz
runs — and reproduces — on a bare container.
"""

import random

import pytest

from _hypothesis_compat import hypothesis, st
from repro.serving import BlockAllocator
from repro.serving.scheduler import prefix_block_hashes

given = hypothesis.given
settings = hypothesis.settings


# ---------------------------------------------------------------------------
# Invariant checker (white-box: this suite owns the allocator's internals)
# ---------------------------------------------------------------------------


def check_invariants(a: BlockAllocator) -> None:
    free = list(a._free)
    allocated = set(a._refs)
    pool = set(range(a.n_reserved, a.n_blocks))
    # free-list ∪ in-use partitions the pool; nothing leaks, nothing is
    # double-tracked, the pool never exceeds its block budget
    assert len(free) == len(set(free)), "duplicate page on the free list"
    assert not (set(free) & allocated), "page both free and allocated"
    assert set(free) | allocated == pool, "pool partition broken"
    assert len(free) + len(allocated) == a.capacity
    # refcount conservation: every reference is an owner's mapped or spare
    # entry, and every refcount is exactly the number of such references
    counts: dict[int, int] = {}
    for pages in a._owned.values():
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
    for pages in a._spare.values():
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
    assert counts == a._refs, "refcounts out of sync with ownership"
    for p, r in a._refs.items():
        assert r >= 1
        assert p >= a.n_reserved, "reserved trash page was allocated"
    # prefix index maps hashes to live pages only, one hash per page, and
    # payloads only hang off registered hashes
    assert len(a._prefix) == len(a._page_hash)
    for h, p in a._prefix.items():
        assert p in allocated, "index maps a freed page"
        assert a._page_hash.get(p) == h
    for h in a._payload:
        assert h in a._prefix, "payload attached to a dropped entry"


# ---------------------------------------------------------------------------
# Directed unit tests: refcount lifecycle, sharing, COW, misuse
# ---------------------------------------------------------------------------


def test_share_keeps_page_alive_until_refcount_zero():
    a = BlockAllocator(8)
    (p,) = a.alloc(0, 1)
    a.register(p, b"h0")
    a.reserve(1, n_new=1, shared=[p])
    assert a.refcount(p) == 2
    a.free(0)
    # still referenced by owner 1: page survives, index entry survives
    assert a.refcount(p) == 1
    assert a.lookup(b"h0") == p
    assert a.free(1) == 2  # p AND owner 1's fresh page hit refcount zero
    assert a.refcount(p) == 0
    assert a.lookup(b"h0") is None
    assert a.available == a.capacity
    check_invariants(a)


def test_reserve_is_atomic_on_exhaustion():
    a = BlockAllocator(4)  # capacity 3
    (p,) = a.alloc(0, 1)
    a.register(p, b"h0")
    with pytest.raises(ValueError, match="exhausted"):
        a.reserve(1, n_new=3, shared=[p], n_spare=1)
    # the failed reservation must not have bumped the shared refcount
    assert a.refcount(p) == 1
    assert a.available == 2
    check_invariants(a)


def test_reserve_rejects_unallocated_shared_page():
    a = BlockAllocator(8)
    with pytest.raises(ValueError, match="unallocated"):
        a.reserve(0, n_new=1, shared=[5])
    check_invariants(a)


def test_reserve_rejects_duplicate_shared_pages():
    """A duplicated page in ``shared`` would double-map one physical block
    into two table positions of the same owner AND double-bump its
    refcount — the first ``free`` would then leave a dangling reference.
    The reservation must be rejected whole, with no refcount side effect."""
    a = BlockAllocator(8)
    (p,) = a.alloc(0, 1)
    a.register(p, b"h0")
    with pytest.raises(ValueError, match="duplicate shared page"):
        a.reserve(1, n_new=1, shared=[p, p])
    # atomic: the failed reservation bumped nothing, owner 1 never existed
    assert a.refcount(p) == 1
    assert 1 not in a._owned
    assert a.available == a.capacity - 1
    check_invariants(a)


def test_cow_fork_swaps_in_the_spare():
    a = BlockAllocator(8)
    (p,) = a.alloc(0, 1)
    a.register(p, b"h0")
    a.reserve(1, n_new=1, shared=[p], n_spare=1)
    old, new = a.cow_fork(1, 0)
    assert old == p and new != p
    assert a.owned(1)[0] == new
    assert a.refcount(p) == 1     # back to the registrant alone
    assert a.refcount(new) == 1
    assert a.spare_count(1) == 0
    assert a.lookup(b"h0") == p   # pristine page stays indexed
    check_invariants(a)


def test_cow_fork_misuse_is_loud():
    a = BlockAllocator(8)
    a.alloc(0, 2)
    with pytest.raises(ValueError, match="nothing is shared"):
        a.cow_fork(0, 0)  # refcount 1: no fork needed, forbidden
    (p,) = [a.owned(0)[0]]
    a.register(p, b"h")
    a.reserve(1, n_new=0, shared=[p])  # sharer WITHOUT a spare
    with pytest.raises(ValueError, match="no spare"):
        a.cow_fork(1, 0)
    check_invariants(a)


def test_double_free_raises():
    a = BlockAllocator(8)
    a.alloc(0, 2)
    a.free(0)
    with pytest.raises(KeyError):
        a.free(0)
    check_invariants(a)


def test_register_misuse_is_loud():
    a = BlockAllocator(8)
    (p, q) = a.alloc(0, 2)
    a.register(p, b"h0")
    with pytest.raises(ValueError, match="already registered"):
        a.register(q, b"h0")   # hash collision with a live entry
    with pytest.raises(ValueError, match="already registered"):
        a.register(p, b"h1")   # one hash per page
    with pytest.raises(ValueError, match="unallocated"):
        a.register(6, b"h2")
    check_invariants(a)


def test_deregister_is_idempotent_and_drops_payload():
    a = BlockAllocator(8)
    (p,) = a.alloc(0, 1)
    a.register(p, b"h0", payload="stuff")
    assert a.payload(b"h0") == "stuff"
    a.deregister(p)
    assert a.lookup(b"h0") is None
    assert a.payload(b"h0") is None
    a.deregister(p)  # no-op
    with pytest.raises(ValueError, match="unregistered"):
        a.set_payload(b"h0", "late")
    check_invariants(a)


def test_longest_prefix_match_is_deep_and_read_only():
    """The partial-prefix probe: returns the deepest CONSECUTIVE leading
    run of resident hashes, stops at the first miss, and never mutates
    allocator state (refcounts, index, free list)."""
    a = BlockAllocator(10)
    pages = a.alloc(0, 3)
    for i, p in enumerate(pages):
        a.register(p, bytes([i]))
    refs = dict(a._refs)
    free = list(a._free)
    assert a.longest_prefix_match([bytes([0]), bytes([1]), bytes([2])]) == pages
    assert a.longest_prefix_match([bytes([0]), bytes([9]), bytes([2])]) == (
        pages[:1]
    )
    assert a.longest_prefix_match([bytes([9])]) == []
    assert a.longest_prefix_match([]) == []
    # probing bumped nothing and freed nothing
    assert a._refs == refs and a._free == free
    check_invariants(a)
    # a deregistered middle block truncates later probes structurally
    a.deregister(pages[1])
    assert a.longest_prefix_match([bytes([i]) for i in range(3)]) == pages[:1]
    check_invariants(a)


def test_prefix_block_hashes_chain_semantics():
    """Chain hashes identify content-at-position: equal padded prefixes
    share hashes, any earlier divergence changes every later hash, and a
    partial trailing block never collides with a full one."""
    h1 = prefix_block_hashes([0, 0, 1, 2, 3, 4, 5, 6], 4)
    h2 = prefix_block_hashes([0, 0, 1, 2, 9, 9, 9, 9], 4)
    assert h1[0] == h2[0]          # same first block
    assert h1[1] != h2[1]          # diverging second block
    h3 = prefix_block_hashes([7, 0, 1, 2, 3, 4, 5, 6], 4)
    assert h3[0] != h1[0] and h3[1] != h1[1]  # early change poisons chain
    full = prefix_block_hashes([1, 2, 3, 4], 4)
    part = prefix_block_hashes([1, 2, 3], 4)
    assert len(full) == len(part) == 1
    assert full[0] != part[0]      # token count disambiguates
    # seeds are uint32-ranged and content-determined
    assert all(0 <= s < 2**32 for _, s in h1)
    assert prefix_block_hashes([0, 0, 1, 2, 3, 4, 5, 6], 4) == h1


# ---------------------------------------------------------------------------
# Property fuzz: random admission/COW/eviction traces
# ---------------------------------------------------------------------------


def _fuzz_trace(seed: int, n_blocks: int, n_ops: int) -> None:
    """Drive one random trace, checking every invariant after every op."""
    rng = random.Random(seed)
    a = BlockAllocator(n_blocks)
    next_owner = 0
    next_hash = 0
    # spilled: owner -> ordered (hash-or-None) list, the allocator-level
    # shadow of the engine's host-side spill store ("preempt" pushes,
    # "restore" pops and re-admits through reserve + re-register)
    spilled: list[list] = []
    # synthetic chains: hash -> page history, so "match"/"suffix_reserve"
    # can build plausible (and implausible) probe sequences
    for _ in range(n_ops):
        op = rng.choice(
            [
                "reserve", "reserve", "register", "fork", "free",
                "deregister", "match", "suffix_reserve", "reserve_dup",
                "preempt", "restore",
            ]
        )
        try:
            if op == "match":
                # probe with a mix of live hashes and junk: the result must
                # be the leading resident run, and probing must not mutate
                registered = list(a.registered_pages().items())
                rng.shuffle(registered)
                probe = [h for _, h in registered[:3]]
                cut = rng.randint(0, len(probe))
                probe.insert(cut, b"\xff-junk")
                refs_before = dict(a._refs)
                got = a.longest_prefix_match(probe)
                want = []
                for h in probe:
                    p = a.lookup(h)
                    if p is None:
                        break
                    want.append(p)
                assert got == want
                assert a._refs == refs_before, "match mutated refcounts"
            elif op == "suffix_reserve":
                # the suffix-prefill admission shape: map the deepest run
                # of a registered chain, take fresh pages for the suffix +
                # decode budget, register the fresh ones under new hashes
                registered = list(a.registered_pages().values())
                probe = registered[: rng.randint(0, min(3, len(registered)))]
                shared = a.longest_prefix_match(probe)
                n_new = rng.randint(0 if shared else 1, 3)
                n_spare = rng.randint(0, 1) if shared else 0
                if a.can_alloc(n_new + n_spare):
                    pages = a.reserve(next_owner, n_new, shared, n_spare)
                    assert pages[: len(shared)] == shared
                    for p in pages[len(shared) :]:
                        if rng.random() < 0.5:
                            a.register(p, next_hash.to_bytes(8, "little"))
                            next_hash += 1
                    next_owner += 1
            elif op == "reserve_dup":
                # adversarial: a duplicated shared page must be rejected
                # whole, with refcounts and ownership left untouched
                registered = list(a.registered_pages())
                if registered:
                    p = rng.choice(registered)
                    refs_before = dict(a._refs)
                    owners_before = set(a._owned)
                    with pytest.raises(ValueError, match="duplicate"):
                        a.reserve(next_owner, 0, [p, p])
                    assert a._refs == refs_before
                    assert set(a._owned) == owners_before
            elif op == "reserve":
                registered = list(a.registered_pages())
                # a random (possibly empty) run of resident pages to share
                shared = rng.sample(
                    registered, rng.randint(0, min(2, len(registered)))
                )
                n_new = rng.randint(0 if shared else 1, 3)
                n_spare = rng.randint(0, 1) if shared else 0
                if a.can_alloc(n_new + n_spare):
                    a.reserve(next_owner, n_new, shared, n_spare)
                    next_owner += 1
            elif op == "register":
                owners = list(a._owned)
                if owners:
                    pages = a.owned(rng.choice(owners))
                    unreg = [
                        p for p in pages if p not in a.registered_pages()
                    ]
                    if unreg:
                        a.register(
                            rng.choice(unreg),
                            next_hash.to_bytes(8, "little"),
                            payload=rng.choice([None, "payload"]),
                        )
                        next_hash += 1
            elif op == "fork":
                candidates = [
                    (o, i)
                    for o, pages in a._owned.items()
                    for i, p in enumerate(pages)
                    if a.refcount(p) > 1 and a.spare_count(o) > 0
                ]
                if candidates:
                    a.cow_fork(*rng.choice(candidates))
            elif op == "free":
                owners = list(a._owned)
                if owners:
                    a.free(rng.choice(owners))
            elif op == "deregister":
                pages = list(a.registered_pages())
                if pages:
                    a.deregister(rng.choice(pages))
            elif op == "preempt":
                # the engine's spill shape: remember which hash each of
                # the owner's pages carried (None for unregistered decode
                # tail pages), then release everything at once — shared
                # pages survive via their other holders
                owners = list(a._owned)
                if owners:
                    owner = rng.choice(owners)
                    rec = [a._page_hash.get(p) for p in a.owned(owner)]
                    a.free(owner)
                    spilled.append(rec)
            elif op == "restore":
                # the engine's restore gate: probe the remembered chain,
                # map whatever prefix is still resident, take fresh pages
                # for the rest, and re-register hashes that went dead
                # with the spill (guarded by lookup, exactly like
                # _gate_restore — a hash may have been re-registered by
                # another chain in the meantime)
                if spilled:
                    rec = spilled.pop(rng.randrange(len(spilled)))
                    probe = [h for h in rec if h is not None]
                    shared = a.longest_prefix_match(probe)
                    n_new = len(rec) - len(shared)
                    if a.can_alloc(n_new):
                        pages = a.reserve(next_owner, n_new, shared)
                        assert pages[: len(shared)] == shared
                        for p, h in zip(
                            pages[len(shared):], rec[len(shared):]
                        ):
                            if h is not None and a.lookup(h) is None:
                                a.register(p, h)
                        next_owner += 1
        finally:
            check_invariants(a)
    # drain: releasing every owner must hand the whole pool back
    for owner in list(a._owned):
        a.free(owner)
        check_invariants(a)
    assert a.available == a.capacity
    assert not a.registered_pages()


@settings(deadline=None, max_examples=25)
@given(
    seed=st.integers(0, 10_000),
    n_blocks=st.integers(3, 24),
    n_ops=st.integers(10, 120),
)
def test_allocator_invariants_under_fuzz(seed, n_blocks, n_ops):
    _fuzz_trace(seed, n_blocks, n_ops)


@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 10_000))
def test_allocator_invariants_under_long_tight_fuzz(seed):
    """A tiny pool under a long trace maximizes recycling pressure: pages
    cycle free → owned → shared → forked → free many times over."""
    _fuzz_trace(seed, 5, 400)
