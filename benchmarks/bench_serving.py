"""Serving-side RACA under load: continuous batching vs static batching,
paged vs dense KV cache, greedy vs WTA stochastic sampling.

A Poisson-ish arrival trace (exponential inter-arrival gaps measured in
decode-step ticks, mixed prompt lengths, mixed per-request token budgets)
drives the continuous-batching engine; the same trace drives the static
reference.  Reported per engine/sampler: tokens/s, mean time-to-first-token
and mean slot occupancy.  The headline system-level claims:

* on mixed-length traffic the scheduler's mid-flight slot refill keeps
  occupancy above the static baseline, with the WTA vote sampler (paper
  §III-B/C, Fig. 6) riding along at full batch width;
* on short-prompt traffic the paged KV cache's decode step beats the dense
  per-slot window by a margin that WIDENS with max_len — the dense step
  pays O(max_len) per token while paged pays O(blocks actually filled).
  Paged/dense decode-step latency is measured steady-state (a warm-up pass
  populates every jit bucket; the reported numbers are second-pass deltas,
  so compiles are excluded), with the paged pool sized to the trace's
  working set — pooling capacity instead of reserving batch·max_len per
  slot is exactly the point of the layout;
* the int8 paged pool (stochastic-rounded codes + scale planes, dequant
  fused into the attention math) is compared against the bf16 pool on the
  same trace (decode-step latency + tokens/s), and an equal-memory
  capacity sweep counts requests ADMITTED at a fixed num_kv_blocks budget
  — int8 pages cost half the K/V bytes, so the same budget admits ~2x;
* prefix sharing on a repeated-prefix trace (the shared-system-prompt
  workload): prefill computations saved via content-hash block reuse,
  admission capacity at an equal num_kv_blocks budget, and a standing
  byte-identity check between the sharing-on and sharing-off token
  streams (validate_report fails the run on divergence);
* sharded paged decode over the local (data, model) host mesh: token
  identity vs the single-device engine and admission capacity scaling
  with the data axis at constant per-device pool memory (run under
  XLA_FLAGS=--xla_force_host_platform_device_count=N for a real
  multi-device mesh; degrades to a 1x1 mesh identity check otherwise);
* self-speculative decoding (draft-k fused decode + one-dispatch verify)
  vs plain decode on the same trace: acceptance rate, tokens per verify
  round, and steady-state tokens/s — byte-identity AND a tokens/s floor
  (ratio >= 1.0) are enforced by validate_report.

Results (tokens/s, TTFT, decode-step ms, occupancy for every engine) are
also written to a JSON file for CI artifact tracking.

    PYTHONPATH=src python -m benchmarks.bench_serving [--dry-run]
        [--out BENCH_serving.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import cost_model as CM
from repro.kernels.backend import FaultConfig
from repro.models import get_model_fns
from repro.serving import (
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    DegradationPolicy,
    FaultInjector,
    RequestState,
    ServeConfig,
    ServingEngine,
    StaticServingEngine,
)

# Keys every report must carry — bench_paged_int8/bench_capacity entries are
# validated per-row below.  validate_report() is run on the freshly written
# JSON by main() AND by CI on the uploaded artifact, so a schema drift fails
# the build loudly instead of silently breaking the perf-trajectory tooling.
REPORT_SCHEMA = {
    "engines": dict,
    "paged_vs_dense": list,
    "paged_int8_vs_bf16": list,
    "int8_capacity_sweep": dict,
    "prefix_sharing": dict,
    "partial_prefix": dict,
    "sharded_decode": dict,
    "preemption": dict,
    "speculative_decode": dict,
    "energy_per_token": dict,
    "fault_tolerance": dict,
    "dry_run": bool,
}
_INT8_ROW_KEYS = {
    "max_len", "block_size", "bf16", "int8", "decode_speedup",
    "tokens_per_s_ratio",
}
_CAPACITY_KEYS = {
    "num_kv_blocks", "blocks_per_request", "admitted_bf16", "admitted_int8",
    "capacity_ratio",
}
_PREFIX_KEYS = {
    "n_requests", "prompt_len", "off", "on", "prefill_savings",
    "tokens_match", "num_kv_blocks", "admitted_off", "admitted_on",
    "capacity_ratio",
}
_PARTIAL_KEYS = {
    "n_requests", "prompt_len", "shared_prefix_len", "prefill_chunk",
    "off", "on", "prefill_token_reduction", "late_ttft_ratio",
    "tokens_match",
}
_SHARDED_KEYS = {
    "mesh", "devices", "single", "sharded", "tokens_match",
    "per_device_kv_blocks", "admitted_single", "admitted_sharded",
    "capacity_ratio",
}
_PREEMPTION_KEYS = {
    "n_batch", "n_interactive", "burst_tick", "on", "off",
    "tokens_match", "interactive_p99_ratio",
}
_SPECULATIVE_KEYS = {
    "speculate_k", "n_requests", "plain", "spec", "acceptance",
    "tokens_per_round", "tokens_per_s_ratio", "tokens_match",
}
_ENERGY_KEYS = {
    "n_requests", "wta_trials", "kv_cache_dtype", "accounting",
    "raca_energy_pj_per_token", "adc1b_energy_pj_per_token",
    "raca_tops_per_w", "adc1b_tops_per_w", "speculative",
}
_FAULT_KEYS = {
    "n_requests", "stuck_rate", "canary_interval", "zero_fault", "faulted",
}
_FAULTED_KEYS = {
    "accounting", "degraded_mode_final", "degraded_mode_max",
    "canary_probes", "canary_failures", "retired_tiles",
    "redundant_read_events", "transitions", "evictions", "all_served",
    "injected",
}


def _expected_counts(acc: dict):
    """Re-derive the accounted event totals from the snapshot's own
    per-event shape counts — the integer-exact reconciliation formula
    shared by the energy and fault-tolerance sections."""
    tc = acc["tokens_computed"]
    return (
        CM.AnalogOpCounts.from_dict(acc["per_token_counts"])
        .scaled(tc["total"])
        + CM.AnalogOpCounts.from_dict(acc["per_sample_counts"])
        .scaled(acc["sample_events"])
        + CM.AnalogOpCounts.from_dict(acc["per_kv_token_counts"])
        .scaled(acc["kv_written_tokens"])
        + CM.AnalogOpCounts.from_dict(acc["per_redundant_counts"])
        .scaled(acc["redundant_read_events"])
    )


def validate_report(report: dict) -> None:
    """Raise ValueError unless ``report`` matches the published schema."""
    for key, typ in REPORT_SCHEMA.items():
        if key not in report:
            raise ValueError(f"BENCH_serving.json missing key {key!r}")
        if not isinstance(report[key], typ):
            raise ValueError(
                f"BENCH_serving.json key {key!r} should be {typ.__name__}, "
                f"got {type(report[key]).__name__}"
            )
    for row in report["paged_int8_vs_bf16"]:
        missing = _INT8_ROW_KEYS - set(row)
        if missing:
            raise ValueError(
                f"paged_int8_vs_bf16 row missing keys {sorted(missing)}"
            )
    missing = _CAPACITY_KEYS - set(report["int8_capacity_sweep"])
    if missing:
        raise ValueError(
            f"int8_capacity_sweep missing keys {sorted(missing)}"
        )
    missing = _PREFIX_KEYS - set(report["prefix_sharing"])
    if missing:
        raise ValueError(
            f"prefix_sharing missing keys {sorted(missing)}"
        )
    if report["prefix_sharing"]["tokens_match"] is not True:
        raise ValueError(
            "prefix_sharing: sharing-on vs sharing-off decode diverged"
        )
    missing = _PARTIAL_KEYS - set(report["partial_prefix"])
    if missing:
        raise ValueError(
            f"partial_prefix missing keys {sorted(missing)}"
        )
    if report["partial_prefix"]["tokens_match"] is not True:
        raise ValueError(
            "partial_prefix: sharing-on vs sharing-off decode diverged"
        )
    # acceptance floor, deterministic (token counts, not timings): the
    # shared-prefix trace must cut computed prefill tokens >= 3x
    if report["partial_prefix"]["prefill_token_reduction"] < 3.0:
        raise ValueError(
            "partial_prefix: prefill-token reduction "
            f"{report['partial_prefix']['prefill_token_reduction']} < 3.0"
        )
    missing = _SHARDED_KEYS - set(report["sharded_decode"])
    if missing:
        raise ValueError(
            f"sharded_decode missing keys {sorted(missing)}"
        )
    if report["sharded_decode"]["tokens_match"] is not True:
        raise ValueError(
            "sharded_decode: mesh-sharded vs single-device decode diverged"
        )
    pre = report["preemption"]
    missing = _PREEMPTION_KEYS - set(pre)
    if missing:
        raise ValueError(f"preemption missing keys {sorted(missing)}")
    # spill/restore safety: every request that COMPLETED in both runs must
    # carry the identical token stream — preemption must never change what
    # a request generates, only when
    if pre["tokens_match"] is not True:
        raise ValueError(
            "preemption: preemption-on vs preemption-off decode diverged"
        )
    if pre["on"]["preemptions"] < 1:
        raise ValueError(
            "preemption: the bursty two-class trace triggered no "
            "preemption — the benchmark is not exercising the policy"
        )
    # the point of preempting: the interactive burst's tail latency must be
    # STRICTLY better with preemption on (the batch victims absorb the wait)
    if not (
        pre["on"]["interactive"]["ttft_p99_ms"]
        < pre["off"]["interactive"]["ttft_p99_ms"]
    ):
        raise ValueError(
            "preemption: interactive p99 TTFT did not improve with "
            "preemption on "
            f"(on={pre['on']['interactive']['ttft_p99_ms']}ms, "
            f"off={pre['off']['interactive']['ttft_p99_ms']}ms)"
        )
    spec = report["speculative_decode"]
    missing = _SPECULATIVE_KEYS - set(spec)
    if missing:
        raise ValueError(
            f"speculative_decode missing keys {sorted(missing)}"
        )
    # the output-distribution contract: speculation must never change what
    # a greedy request generates, only how fast — CI fails on divergence
    if spec["tokens_match"] is not True:
        raise ValueError(
            "speculative_decode: speculative-on vs plain decode diverged"
        )
    # the point of speculating: per-token cost amortizes over the draft
    # run, so steady-state tokens/s must be no worse than plain decode
    if spec["tokens_per_s_ratio"] < 1.0:
        raise ValueError(
            "speculative_decode: tokens/s ratio "
            f"{spec['tokens_per_s_ratio']} < 1.0 — speculation lost to "
            "plain decode on the serving trace"
        )
    en = report["energy_per_token"]
    missing = _ENERGY_KEYS - set(en)
    if missing:
        raise ValueError(
            f"energy_per_token missing keys {sorted(missing)}"
        )
    acc = en["accounting"]
    # EXACT count reconciliation from the artifact alone: the accounted
    # event totals must equal tokens-computed x per-token shape counts
    # (plus sampling and KV-write terms) as integers — the accounting's
    # invariance contract, enforced on every committed report
    tc = acc["tokens_computed"]
    if tc["prefill"] + tc["decode"] + tc["draft"] != tc["total"]:
        raise ValueError(
            f"energy_per_token: tokens_computed does not sum: {tc}"
        )
    expected = _expected_counts(acc)
    if expected.as_dict() != acc["counts"]:
        raise ValueError(
            "energy_per_token: event counts do not reconcile against "
            f"tokens computed — expected {expected.as_dict()}, "
            f"reported {acc['counts']}"
        )
    # pricing reconciliation: re-price the reconciled counts with the
    # Table I cost model and match the reported energies
    prices = CM.price_counts(expected)
    for scheme in ("raca", "adc1b"):
        gross = acc[scheme]["energy_pj_gross"]
        want = prices[f"{scheme}_energy_pj"]
        if abs(gross - want) > 1e-6 * max(want, 1.0):
            raise ValueError(
                f"energy_per_token: {scheme} gross energy {gross} != "
                f"re-priced {want}"
            )
        per = acc[scheme]["energy_pj_per_token"]
        want_per = gross / max(acc["tokens_published"], 1)
        if abs(per - want_per) > 1e-6 * max(want_per, 1.0):
            raise ValueError(
                f"energy_per_token: {scheme} per-token energy {per} != "
                f"gross/published {want_per}"
            )
        if abs(en[f"{scheme}_energy_pj_per_token"] - per) > 1e-9 * max(
            per, 1.0
        ):
            raise ValueError(
                f"energy_per_token: top-level {scheme} per-token copy "
                "diverged from the accounting section"
            )
    # the paper's point, on served traffic: ADC-free RACA readout must
    # price BELOW the 1-bit-ADC scheme for the same event stream
    if not (
        en["raca_energy_pj_per_token"] < en["adc1b_energy_pj_per_token"]
    ):
        raise ValueError(
            "energy_per_token: RACA pricing "
            f"({en['raca_energy_pj_per_token']} pJ/tok) is not below "
            f"1-bit-ADC ({en['adc1b_energy_pj_per_token']} pJ/tok)"
        )
    spe = en["speculative"]
    if spe["tokens_match"] is not True:
        raise ValueError(
            "energy_per_token: speculative vs plain published streams "
            "diverged — the energy comparison is not like-for-like"
        )
    # rejected drafts burn energy without publishing: per published
    # token, speculation can only cost MORE energy than plain decode
    if spe["overhead_ratio"] < 1.0:
        raise ValueError(
            "energy_per_token: speculative per-published-token energy "
            f"ratio {spe['overhead_ratio']} < 1.0 — drafted work is "
            "being under-accounted"
        )
    ft = report["fault_tolerance"]
    missing = _FAULT_KEYS - set(ft)
    if missing:
        raise ValueError(f"fault_tolerance missing keys {sorted(missing)}")
    fa = ft["faulted"]
    missing = _FAULTED_KEYS - set(fa)
    if missing:
        raise ValueError(
            f"fault_tolerance.faulted missing keys {sorted(missing)}"
        )
    # the zero-knob contract: sim_faulty with every fault knob at zero
    # must be BIT-IDENTICAL to the plain sim backend on a served trace
    if ft["zero_fault"]["tokens_match"] is not True:
        raise ValueError(
            "fault_tolerance: zero-knob sim_faulty stream diverged from "
            "the sim backend — the fault model is not identity at rest"
        )
    # liveness under injected device faults: every request the engine did
    # not explicitly evict (typed reason) must have published tokens
    if fa["all_served"] is not True:
        raise ValueError(
            "fault_tolerance: a non-evicted request ended without "
            "published tokens under the fault schedule"
        )
    if fa["canary_failures"] < 1:
        raise ValueError(
            "fault_tolerance: the injected comparator offset never "
            "failed a canary probe — detection is not being exercised"
        )
    # the degradation ladder must have tripped AND fully recovered once
    # the injected fault was lifted (reversibility contract)
    if not fa["transitions"]:
        raise ValueError("fault_tolerance: no degradation transitions")
    if fa["degraded_mode_max"] < 1:
        raise ValueError("fault_tolerance: degradation never engaged")
    if fa["degraded_mode_final"] != 0:
        raise ValueError(
            "fault_tolerance: engine did not recover to degraded_mode 0 "
            "after recover_device "
            f"(final level {fa['degraded_mode_final']})"
        )
    # redundant comparator re-reads must be priced: events recorded by
    # the backend reconcile integer-exactly against the count ledger
    facc = fa["accounting"]
    if fa["redundant_read_events"] != facc["redundant_read_events"]:
        raise ValueError(
            "fault_tolerance: redundant_read_events metric diverged "
            "from the accounting snapshot"
        )
    if fa["redundant_read_events"] < 1:
        raise ValueError(
            "fault_tolerance: the degraded engine recorded no redundant "
            "comparator re-reads at level >= 2"
        )
    if _expected_counts(facc).as_dict() != facc["counts"]:
        raise ValueError(
            "fault_tolerance: faulted event counts do not reconcile "
            "against tokens computed + redundant reads — expected "
            f"{_expected_counts(facc).as_dict()}, "
            f"reported {facc['counts']}"
        )


def make_trace(
    seed: int,
    n_req: int,
    mean_gap_ticks: float,
    prompt_len_range: tuple[int, int],
    new_tokens_range: tuple[int, int],
    vocab: int,
) -> list[tuple[int, list[int], int]]:
    """(arrival_tick, prompt, max_new_tokens) rows, arrival-sorted.

    Arrivals are a Poisson-ish process over engine ticks (exponential gaps)
    rather than wall clock, so the trace is deterministic for a seed and
    independent of host speed.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n_req):
        t += rng.exponential(mean_gap_ticks)
        plen = int(rng.integers(*prompt_len_range))
        prompt = rng.integers(1, vocab, size=plen).tolist()
        budget = int(rng.integers(*new_tokens_range))
        trace.append((int(t), prompt, budget))
    return trace


def drive_continuous(engine: ServingEngine, trace) -> None:
    """Feed the trace by tick index; drain after the last arrival."""
    i, tick = 0, 0
    while i < len(trace) or engine.sched.has_work():
        while i < len(trace) and trace[i][0] <= tick:
            _, prompt, budget = trace[i]
            engine.submit(prompt, budget)
            i += 1
        engine.tick()
        tick += 1


def drive_static(engine: StaticServingEngine, trace) -> None:
    """Feed the same tick-indexed trace to the static engine.

    Static batching cannot admit mid-flight: each ``step()`` wave consumes
    as many ticks as it ran decode steps, and requests arriving during a
    wave wait in the queue — the TTFT / occupancy cost being measured.
    Requests whose arrival tick fell inside a finished wave are submitted
    with a backdated timestamp (measured seconds/tick), so their queue wait
    counts toward static TTFT just as it does for the continuous engine.
    """
    i, tick = 0, 0
    tick_wall = time.perf_counter()
    sec_per_tick = 0.0
    while i < len(trace) or engine.pending():
        while i < len(trace) and trace[i][0] <= tick:
            _, prompt, budget = trace[i]
            arrival_wall = tick_wall - (tick - trace[i][0]) * sec_per_tick
            engine.submit(prompt, budget, submit_time=arrival_wall)
            i += 1
        if engine.pending():
            before = engine.metrics().decode_steps
            t0 = time.perf_counter()
            engine.step()
            steps = max(engine.metrics().decode_steps - before, 1)
            sec_per_tick = (time.perf_counter() - t0) / steps
            tick += steps
        else:
            tick += 1
        tick_wall = time.perf_counter()


def _bench(cfg, params, trace, serve_cfg):
    eng = ServingEngine(params, cfg, serve_cfg)
    drive_continuous(eng, trace)
    return eng.metrics()


def _metrics_dict(m) -> dict:
    return {
        "tokens_per_s": round(m.tokens_per_s, 1),
        "ttft_ms": round(m.ttft_mean * 1e3, 2),
        "decode_step_ms": round(m.decode_step_ms, 3),
        "occupancy": round(m.occupancy_mean, 3),
        "completed": m.completed,
        "decode_steps": m.decode_steps,
    }


def _steady_delta(m0, m1) -> dict:
    """Second-pass (warm-jit) metrics from two cumulative snapshots."""
    steps = m1.decode_steps - m0.decode_steps
    comp = m1.completed - m0.completed
    ttft = (
        m1.ttft_mean * m1.completed - m0.ttft_mean * m0.completed
    ) / max(comp, 1)
    occ = (
        m1.occupancy_mean * m1.decode_steps
        - m0.occupancy_mean * m0.decode_steps
    ) / max(steps, 1)
    wall = m1.wall_time - m0.wall_time
    return {
        "tokens_per_s": round(
            (m1.total_tokens - m0.total_tokens) / max(wall, 1e-9), 1
        ),
        "ttft_ms": round(ttft * 1e3, 2),
        "decode_step_ms": round(
            (m1.decode_time - m0.decode_time) * 1e3 / max(steps, 1), 3
        ),
        "occupancy": round(occ, 3),
        "completed": comp,
        "decode_steps": steps,
    }


def bench_paged_vs_dense(
    cfg, params, max_len: int, n_req: int, block_size: int = 16
) -> dict:
    """Dense vs paged decode at one max_len point, short-prompt trace.

    The paged pool is sized to the trace's working set (every slot holding
    its largest possible request, plus slack) rather than dense-parity
    batch·max_len — shared capacity is the layout's premise.  Occupancy is
    equal by construction: both engines run the identical trace through the
    identical scheduler."""
    max_plen, max_budget = 10, 16
    serve = dict(max_batch=4, max_new_tokens=max_budget, max_len=max_len)
    trace = make_trace(
        seed=1, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, max_plen),
        new_tokens_range=(6, max_budget), vocab=cfg.vocab,
    )
    out = {"max_len": max_len, "block_size": block_size}
    for layout in ("dense", "paged"):
        kw = dict(serve, kv_layout=layout)
        if layout == "paged":
            # working set per request: prompts land in the smallest prefill
            # bucket covering max_plen, plus the full decode budget
            bucket = next(
                b for b in ServeConfig(**serve).buckets() if b >= max_plen
            )
            per_req = -(-(bucket + max_budget) // block_size)
            kw.update(
                kv_block_size=block_size,
                num_kv_blocks=serve["max_batch"] * per_req + 3,
            )
        eng = ServingEngine(params, cfg, ServeConfig(**kw))
        drive_continuous(eng, trace)  # warm-up: compiles every bucket
        m0 = eng.metrics()
        drive_continuous(eng, trace)  # measured steady-state pass
        out[layout] = _steady_delta(m0, eng.metrics())
    out["decode_speedup"] = round(
        out["dense"]["decode_step_ms"]
        / max(out["paged"]["decode_step_ms"], 1e-9),
        2,
    )
    return out


def bench_paged_int8(
    cfg, params, max_len: int, n_req: int, block_size: int = 16
) -> dict:
    """bf16 vs int8 paged decode at one max_len point.

    Same trace, same scheduler, dense-parity pools for both (the latency
    comparison isolates the per-token decode cost: int8 halves the K/V
    bytes a decode step streams and fuses the dequant into the attention
    math).  Steady-state methodology matches bench_paged_vs_dense."""
    max_plen, max_budget = 10, 16
    serve = dict(
        max_batch=4, max_new_tokens=max_budget, max_len=max_len,
        kv_layout="paged", kv_block_size=block_size,
    )
    trace = make_trace(
        seed=2, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, max_plen),
        new_tokens_range=(6, max_budget), vocab=cfg.vocab,
    )
    out = {"max_len": max_len, "block_size": block_size}
    for label, dt in (("bf16", "same"), ("int8", "int8")):
        mcfg = dataclasses.replace(cfg, kv_cache_dtype=dt)
        eng = ServingEngine(params, mcfg, ServeConfig(**serve))
        drive_continuous(eng, trace)  # warm-up: compiles every bucket
        m0 = eng.metrics()
        drive_continuous(eng, trace)  # measured steady-state pass
        out[label] = _steady_delta(m0, eng.metrics())
    out["decode_speedup"] = round(
        out["bf16"]["decode_step_ms"]
        / max(out["int8"]["decode_step_ms"], 1e-9),
        2,
    )
    out["tokens_per_s_ratio"] = round(
        out["int8"]["tokens_per_s"]
        / max(out["bf16"]["tokens_per_s"], 1e-9),
        2,
    )
    return out


def bench_prefix_sharing(cfg, params, n_req: int = 12) -> dict:
    """Repeated-prefix trace: the same prompt submitted ``n_req`` times.

    The trace every prefix cache is built for (shared system prompt /
    few-shot header).  Measured end to end through the engine:

    * prefill work saved — with sharing on, every repeat that overlaps a
      resident copy maps the prompt blocks and samples its first token
      from the stored last-token logits instead of recomputing the bucket
      prefill (``metrics.prefills`` vs ``prefix_hits``);
    * admission capacity at equal ``num_kv_blocks`` — a tight pool admits
      the original (full block budget) plus repeats at one decode-budget
      allocation each, vs ``floor(capacity / full_budget)`` without
      sharing;
    * safety — the sharing-on and sharing-off token streams must be
      IDENTICAL (``tokens_match``; validate_report fails the run on a
      divergence, making CI a standing byte-identity check).
    """
    prompt = list(range(1, 17))  # bucket 16, block-aligned
    budget = 8
    serve = dict(
        max_batch=4, max_new_tokens=budget, max_len=64,
        kv_layout="paged", kv_block_size=8,
    )
    out: dict = {"n_requests": n_req, "prompt_len": len(prompt)}
    streams = {}
    for label, share in (("off", False), ("on", True)):
        eng = ServingEngine(
            params, cfg, ServeConfig(**serve, enable_prefix_sharing=share)
        )
        rids = [eng.submit(prompt, budget) for _ in range(n_req)]
        outs = eng.run()
        streams[label] = [outs[r] for r in rids]
        m = eng.metrics()
        out[label] = {
            "prefills": m.prefills,
            "prefix_hits": m.prefix_hits,
            "cow_forks": m.cow_forks,
            "tokens_per_s": round(m.tokens_per_s, 1),
            "ttft_ms": round(m.ttft_mean * 1e3, 2),
        }
    out["prefill_savings"] = round(
        1.0 - out["on"]["prefills"] / max(out["off"]["prefills"], 1), 2
    )
    out["tokens_match"] = streams["on"] == streams["off"]

    # admission capacity at an equal, deliberately tight block budget
    out["num_kv_blocks"] = 8
    for label, share in (("off", False), ("on", True)):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(
                **dict(serve, max_batch=8), num_kv_blocks=8,
                enable_prefix_sharing=share,
            ),
        )
        for _ in range(8):
            eng.submit(prompt, budget)
        eng.tick()
        out[f"admitted_{label}"] = sum(
            1 for r in eng.sched.all_requests()
            if r.state is not RequestState.QUEUED
        )
    out["capacity_ratio"] = round(
        out["admitted_on"] / max(out["admitted_off"], 1), 2
    )
    return out


def bench_partial_prefix(cfg, params, n_req: int = 10) -> dict:
    """Shared-system-prompt trace: a 56-token common prefix with short
    unique suffixes, arrivals staggered so late requests land while
    earlier ones are mid-decode.

    The workload suffix-only prefill exists for.  With sharing on, every
    late arrival maps the resident prefix blocks and computes ONLY its
    8-token suffix (one `prefill_chunk` tick) instead of the whole
    64-token bucket — measured end to end through the engine:

    * computed prefill tokens (``metrics.prefill_tokens``) drop ≥ 3× —
      deterministic token counts, enforced by ``validate_report``;
    * TTFT for the late arrivals shrinks (one suffix chunk vs a full
      bucket of chunks injected between decode steps), reported as
      ``late_ttft_ratio`` (timing, not validated);
    * the on/off token streams must be IDENTICAL (``tokens_match`` —
      CI fails on divergence).
    """
    prefix = list(range(1, 57))               # 56 shared tokens
    suffix_len, budget = 8, 8
    prompts = [prefix + [200 + i] * suffix_len for i in range(n_req)]
    serve = dict(
        max_batch=4, max_new_tokens=budget, max_len=128,
        kv_layout="paged", kv_block_size=8, prefill_chunk=16,
    )
    out: dict = {
        "n_requests": n_req,
        "prompt_len": len(prompts[0]),
        "shared_prefix_len": len(prefix),
        "prefill_chunk": serve["prefill_chunk"],
    }
    streams = {}
    for label, share in (("off", False), ("on", True)):
        eng = ServingEngine(
            params, cfg, ServeConfig(**serve, enable_prefix_sharing=share)
        )

        def drive_pass():
            rids: list[int] = []
            i = tick = 0
            while i < len(prompts) or eng.sched.has_work():
                while i < len(prompts) and 2 * i <= tick:
                    rids.append(eng.submit(prompts[i], budget))
                    i += 1
                eng.tick()
                tick += 1
            return rids

        warm = drive_pass()   # compiles every (bucket, chunk) shape
        m0 = eng.metrics()
        rids = drive_pass()   # measured steady-state pass
        outs = {r.rid: r.output for r in eng.sched.all_requests()}
        streams[label] = [outs[r] for r in warm + rids]
        m = eng.metrics()
        # late arrivals land while earlier requests are mid-decode; their
        # TTFT is the interleaved-prefill responsiveness being measured
        late = [eng.sched.request(r).ttft for r in rids[1:]]
        out[label] = {
            "prefills": m.prefills - m0.prefills,
            "prefix_partial_hits": (
                m.prefix_partial_hits - m0.prefix_partial_hits
            ),
            "prefill_tokens": m.prefill_tokens - m0.prefill_tokens,
            "prefill_tokens_saved": (
                m.prefill_tokens_saved - m0.prefill_tokens_saved
            ),
            "late_ttft_ms": round(float(np.mean(late)) * 1e3, 2),
        }
    out["prefill_token_reduction"] = round(
        out["off"]["prefill_tokens"] / max(out["on"]["prefill_tokens"], 1),
        2,
    )
    out["late_ttft_ratio"] = round(
        out["on"]["late_ttft_ms"] / max(out["off"]["late_ttft_ms"], 1e-9),
        2,
    )
    out["tokens_match"] = streams["on"] == streams["off"]
    return out


def bench_int8_capacity(cfg, params, num_kv_blocks: int = 9) -> dict:
    """Equal-memory admission sweep: requests admitted on the first tick at
    a fixed ``num_kv_blocks`` budget.  int8 pages cost half the K/V bytes,
    so the same budget holds ~2x the pages and the BlockAllocator admits
    ~2x the requests — quantization's capacity win, measured end to end
    through the admission gate."""
    block_size, budget = 8, 8
    prompt = [1, 2, 3]  # bucket 8 + budget 8 -> 2 blocks per request
    out = {
        "num_kv_blocks": num_kv_blocks,
        "blocks_per_request": 2,
    }
    for label, dt in (("bf16", "same"), ("int8", "int8")):
        mcfg = dataclasses.replace(cfg, kv_cache_dtype=dt)
        sc = ServeConfig(
            max_batch=32, max_new_tokens=budget, max_len=64,
            kv_layout="paged", kv_block_size=block_size,
            num_kv_blocks=num_kv_blocks,
            # identical prompts would ALSO share pages — sharing off to
            # isolate the dtype-driven capacity factor (the sharing win is
            # measured by bench_prefix_sharing)
            enable_prefix_sharing=False,
        )
        eng = ServingEngine(params, mcfg, sc)
        for _ in range(32):
            eng.submit(prompt, budget)
        eng.tick()
        out[f"admitted_{label}"] = sum(
            1 for r in eng.sched.all_requests()
            if r.state is not RequestState.QUEUED
        )
    out["capacity_ratio"] = round(
        out["admitted_int8"] / max(out["admitted_bf16"], 1), 2
    )
    return out


def bench_sharded_decode(cfg, params, n_req: int = 8) -> dict:
    """Sharded paged decode over the local ``(data, model)`` host mesh.

    All local devices go to the data axis (``model=1``: data-axis
    sharding preserves every reduction order, so the token-identity
    check is exact, not tie-lucky).  Two end-to-end claims:

    * safety — the same arrival trace through the unsharded engine and
      the mesh-sharded engine must produce IDENTICAL token streams
      (``tokens_match``; validate_report fails the run on divergence);
    * capacity — at a FIXED per-device block budget the sharded pool's
      page axis spreads over data, so total admission capacity scales
      with the data axis at constant per-device memory.  Measured
      through the admission gate like the int8 sweep: requests admitted
      on the first tick at ``num_kv_blocks = per_device · data``.

    On a single-device host (no ``--xla_force_host_platform_device_count``)
    this degrades to a 1×1 mesh: the identity check still runs (and is
    the byte-identity contract), the capacity ratio is 1.0.
    """
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(model=1)  # every local device on the data axis
    data, model = (int(s) for s in mesh.devices.shape)
    serve = dict(
        max_batch=4, max_new_tokens=8, max_len=64,
        kv_layout="paged", kv_block_size=8,
    )
    out: dict = {"mesh": {"data": data, "model": model},
                 "devices": data * model}

    trace = make_trace(
        seed=3, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, 12), new_tokens_range=(3, 9), vocab=cfg.vocab,
    )
    streams = {}
    for label, m in (("single", None), ("sharded", mesh)):
        eng = ServingEngine(params, cfg, ServeConfig(**serve, mesh=m))
        drive_continuous(eng, trace)
        streams[label] = {
            r.rid: r.output for r in eng.sched.all_requests()
            if r.state is RequestState.DONE
        }
        met = eng.metrics()
        out[label] = {
            "tokens_per_s": round(met.tokens_per_s, 1),
            "decode_step_ms": round(met.decode_step_ms, 3),
            "completed": met.completed,
        }
    out["tokens_match"] = streams["single"] == streams["sharded"]

    # admission capacity at a fixed PER-DEVICE budget: the sharded pool
    # holds per_device·data pages at the same bytes per device
    per_device = 8
    out["per_device_kv_blocks"] = per_device
    prompt = [1, 2, 3]  # bucket 8 + budget 8 -> 2 blocks per request
    for label, m, blocks in (
        ("admitted_single", None, per_device),
        ("admitted_sharded", mesh, per_device * data),
    ):
        eng = ServingEngine(
            params, cfg,
            ServeConfig(
                **dict(serve, max_batch=32), num_kv_blocks=blocks,
                enable_prefix_sharing=False, mesh=m,
            ),
        )
        for _ in range(32):
            eng.submit(prompt, 8)
        eng.tick()
        out[label] = sum(
            1 for r in eng.sched.all_requests()
            if r.state is not RequestState.QUEUED
        )
    out["capacity_ratio"] = round(
        out["admitted_sharded"] / max(out["admitted_single"], 1), 2
    )
    return out


def bench_preemption(cfg, params, n_each: int = 3) -> dict:
    """Bursty two-class trace: batch jobs saturate the slots, then an
    interactive burst arrives mid-decode at higher priority.

    The SLO scenario preemption exists for.  With preemption ON the
    engine spills the lowest-priority decoding victims to host (pages +
    recurrent state), seats the burst immediately, and restores the
    victims afterwards through the normal admission gate; with it OFF the
    burst queues behind the batch jobs' full decode budgets.  Reported
    per mode: per-class p50/p99 TTFT and completion latency, preemption /
    restore counts.  Two claims are ENFORCED by ``validate_report``:

    * ``tokens_match`` — every request completing in both runs carries an
      identical token stream (spill/restore byte identity, end to end);
    * the interactive class's p99 TTFT is STRICTLY better with
      preemption on (the batch victims absorb the wait).
    """
    batch_budget, inter_budget, burst_tick = 24, 4, 4
    serve = dict(
        max_batch=2, max_new_tokens=batch_budget, max_len=128,
        kv_layout="paged", kv_block_size=8, prefill_buckets=(16,),
    )
    batch_prompts = [
        list(range(1 + i, 13 + i)) for i in range(n_each)
    ]
    inter_prompts = [
        list(range(100 + i, 109 + i)) for i in range(n_each)
    ]
    out: dict = {
        "n_batch": n_each, "n_interactive": n_each,
        "burst_tick": burst_tick,
    }
    streams: dict[str, dict] = {}
    for label, enable in (("off", False), ("on", True)):
        eng = ServingEngine(
            params, cfg, ServeConfig(**serve, enable_preemption=enable)
        )
        rids: dict[str, list[int]] = {"batch": [], "interactive": []}
        for p in batch_prompts:
            rids["batch"].append(
                eng.submit(p, batch_budget, priority=PRIORITY_BATCH)
            )
        tick = 0
        burst_sent = False
        while eng.sched.has_work():
            if tick >= burst_tick and not burst_sent:
                for p in inter_prompts:
                    rids["interactive"].append(
                        eng.submit(
                            p, inter_budget,
                            priority=PRIORITY_INTERACTIVE,
                        )
                    )
                burst_sent = True
            eng.tick()
            tick += 1
        m = eng.metrics()
        streams[label] = {
            r.rid: r.output for r in eng.sched.all_requests()
        }
        out[label] = {
            "preemptions": m.preemptions,
            "restores": m.restores,
            "batch": m.latency_by_class.get(PRIORITY_BATCH, {}),
            "interactive": m.latency_by_class.get(
                PRIORITY_INTERACTIVE, {}
            ),
        }
    out["tokens_match"] = streams["on"] == streams["off"]
    out["interactive_p99_ratio"] = round(
        out["on"]["interactive"].get("ttft_p99_ms", 0.0)
        / max(out["off"]["interactive"].get("ttft_p99_ms", 1e-9), 1e-9),
        3,
    )
    return out


def bench_speculative(
    cfg, params, n_req: int = 10, k: int = 4, passes: int = 3
) -> dict:
    """Self-speculative decoding vs plain decode on the same mixed trace.

    Every decoding slot drafts ``k`` tokens per tick with the fused decode
    step and verifies the run in one read-only pass — ONE device dispatch
    and one host sync per round instead of per token, so per-tick host
    overhead amortizes over the accepted run.  Two claims are ENFORCED by
    ``validate_report``:

    * ``tokens_match`` — greedy streams are byte-identical speculative-on
      vs plain (speculation changes latency, never output);
    * ``tokens_per_s_ratio >= 1.0`` — steady-state throughput must not
      lose to plain decode (warm-up pass first, then best-of-``passes``
      re-drives of the same trace per engine, plain/spec interleaved so
      transient host noise hits both; the max filters scheduler jitter,
      same spirit as the paged/int8 sections' second-pass deltas).

    Speculation's win is host-side: the draft run does the SAME model
    math as k plain steps, so tokens/s only improves by amortizing the
    per-tick host work + dispatch + sync over the accepted run.  Measure
    it on a dispatch-bound config (the smoke model) — on a compute-bound
    model the ratio pins to ~1.0 by construction.

    Acceptance < 1.0 on a greedy trace is budget truncation, not
    mismatch: drafts past a request's remaining budget are discarded at
    its "length" eviction but still count as drafted.
    """
    serve = dict(
        max_batch=3, max_new_tokens=16, max_len=128,
        kv_layout="paged", kv_block_size=8,
    )
    trace = make_trace(
        seed=4, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, 12), new_tokens_range=(8, 17),
        vocab=cfg.vocab,
    )
    out: dict = {"speculate_k": k, "n_requests": n_req, "passes": passes}
    streams = {}
    engines = {}
    for label, kk in (("plain", 0), ("spec", k)):
        eng = ServingEngine(
            params, cfg, ServeConfig(**serve, speculate_k=kk)
        )
        drive_continuous(eng, trace)  # warm-up: compiles buckets + windows
        engines[label] = eng
    # measured passes INTERLEAVED plain/spec so transient machine noise
    # (another process, a frequency shift) hits both engines, not just
    # whichever happened to run second — the ratio is what's enforced
    best: dict = {}
    for _ in range(passes):
        for label, eng in engines.items():
            m0 = eng.metrics()
            drive_continuous(eng, trace)  # steady-state re-drive
            d = _steady_delta(m0, eng.metrics())
            if (
                label not in best
                or d["tokens_per_s"] > best[label]["tokens_per_s"]
            ):
                best[label] = d
    for label, eng in engines.items():
        streams[label] = [r.output for r in eng.sched.all_requests()]
        out[label] = best[label]
        if label == "spec":
            m = eng.metrics()
            out["acceptance"] = round(m.spec_acceptance, 3)
            out["tokens_per_round"] = round(m.spec_tokens_per_round, 2)
            out[label]["spec_rounds"] = m.spec_rounds
    out["tokens_per_s_ratio"] = round(
        out["spec"]["tokens_per_s"]
        / max(out["plain"]["tokens_per_s"], 1e-9),
        2,
    )
    out["tokens_match"] = streams["plain"] == streams["spec"]
    return out


def bench_energy_per_token(cfg, params, n_req: int = 8) -> dict:
    """Energy-per-token accounting on the standard mixed trace.

    Drives the full analog-event surface at once — int8 KV pool
    (stochastic-rounding events) + WTA sampling head (comparator votes
    per emitted token) — through the Sim device backend, then prices the
    event stream under both readout schemes of the paper's Table I:
    RACA (ADC-free comparator readout) vs the 1-bit-ADC baseline.  The
    committed numbers are deterministic: counts are exact invariants of
    (tokens computed x model shape), reconciled integer-exactly by
    ``validate_report``, and the pricing is a pure function of the
    counts — no timing anywhere in this section.

    A speculative ride-along re-runs the trace greedily, plain vs
    ``speculate_k=2``: the published streams are byte-identical, but the
    speculative engine forwards every drafted AND verify position, so
    its gross energy is strictly higher — ``overhead_ratio`` reports the
    per-published-token cost of rejected drafts (>= 1.0 is enforced:
    drafted work must never be under-accounted).
    """
    mcfg = dataclasses.replace(
        cfg, kv_cache_dtype="int8", wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    serve = dict(
        max_batch=4, max_new_tokens=12, max_len=128,
        kv_layout="paged", kv_block_size=16,
    )
    trace = make_trace(
        seed=5, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, 12), new_tokens_range=(4, 13),
        vocab=cfg.vocab,
    )
    eng = ServingEngine(params, mcfg, ServeConfig(**serve))
    drive_continuous(eng, trace)
    a = eng.metrics().analog
    out: dict = {
        "n_requests": n_req,
        "wta_trials": mcfg.analog.wta_trials,
        "kv_cache_dtype": mcfg.kv_cache_dtype,
        "accounting": a,
        "raca_energy_pj_per_token": a["raca"]["energy_pj_per_token"],
        "adc1b_energy_pj_per_token": a["adc1b"]["energy_pj_per_token"],
        "raca_tops_per_w": round(a["raca"]["tops_per_w_effective"], 4),
        "adc1b_tops_per_w": round(a["adc1b"]["tops_per_w_effective"], 4),
    }

    # speculative ride-along: identical greedy streams, honest gross cost
    gcfg = dataclasses.replace(cfg, wta_head=False)
    spec = {"speculate_k": 2}
    streams = {}
    for label, kk in (("plain", 0), ("spec", spec["speculate_k"])):
        e = ServingEngine(
            params, gcfg, ServeConfig(**serve, speculate_k=kk)
        )
        drive_continuous(e, trace)
        streams[label] = {
            r.rid: r.output for r in e.sched.all_requests()
        }
        sa = e.metrics().analog
        spec[label] = {
            "tokens_published": sa["tokens_published"],
            "tokens_computed": sa["tokens_computed"],
            "raca_energy_pj_gross": sa["raca"]["energy_pj_gross"],
            "raca_energy_pj_per_published_token": (
                sa["raca"]["energy_pj_per_token"]
            ),
        }
    spec["tokens_match"] = streams["plain"] == streams["spec"]
    spec["overhead_ratio"] = round(
        spec["spec"]["raca_energy_pj_per_published_token"]
        / max(spec["plain"]["raca_energy_pj_per_published_token"], 1e-9),
        3,
    )
    out["speculative"] = spec
    return out


def bench_fault_tolerance(cfg, params, n_req: int = 8) -> dict:
    """The analog fault model end to end: identity at rest, the full
    detect/mitigate/degrade/recover loop under an injected device fault.

    Two runs on the same WTA trace:

    * ``zero_fault`` — the ``sim_faulty`` backend with every knob at
      zero against plain ``sim``: published token streams must be
      byte-identical (the fault model is exact identity at rest;
      validate_report enforces it on the committed artifact).
    * ``faulted`` — seeded stuck cells from tick 0 plus an injected
      comparator offset (``degrade_device`` at tick 4, lifted by
      ``recover_device`` at tick 10).  The per-tick canary probe
      catches the stuck cells (tile retirement clears them) and then
      the offset (the degradation ladder climbs to load shedding);
      after recovery the ladder walks back to 0.  Enforced downstream:
      every non-evicted request published tokens, the canary failed at
      least once, transitions were recorded AND reversed, and the
      redundant comparator re-reads taken at ladder level >= 2
      reconcile integer-exactly in the energy ledger.
    """
    mcfg = dataclasses.replace(
        cfg, wta_head=True,
        analog=dataclasses.replace(cfg.analog, wta_trials=8),
    )
    serve = dict(
        max_batch=4, max_new_tokens=12, max_len=128,
        kv_layout="paged", kv_block_size=16,
    )
    trace = make_trace(
        seed=11, n_req=n_req, mean_gap_ticks=1.0,
        prompt_len_range=(2, 12), new_tokens_range=(4, 13),
        vocab=cfg.vocab,
    )

    # identity at rest: all-zero fault knobs vs the plain sim backend
    streams = {}
    for label, bk in (("sim", "sim"), ("sim_faulty", "sim_faulty")):
        e = ServingEngine(
            params, mcfg, ServeConfig(**serve, device_backend=bk)
        )
        drive_continuous(e, trace)
        streams[label] = {r.rid: r.output for r in e.sched.all_requests()}
    zero_fault = {
        "tokens_match": streams["sim"] == streams["sim_faulty"],
    }

    # the fault loop: stuck cells from tick 0, comparator offset injected
    # mid-run and lifted again; canary every tick, retirement + ladder on
    stuck_rate = 0.02
    inj = (
        FaultInjector()
        .at(4, "degrade_device", comparator_offset=2.0)
        .at(10, "recover_device")
    )
    eng = ServingEngine(
        params, mcfg,
        ServeConfig(
            **serve,
            device_backend="sim_faulty",
            device_fault_config=FaultConfig(seed=0, stuck_rate=stuck_rate),
            canary_interval=1,
            tile_retire_threshold=stuck_rate / 2,
            degradation=DegradationPolicy(),
            fault_injector=inj,
        ),
    )
    drive_continuous(eng, trace)
    # idle recovery: the trace can drain while the ladder is still up
    # (recover_after clean canary passes per rung) — keep ticking the
    # empty engine so the canary can walk it back to 0, bounded so a
    # recovery bug degrades to a failed check instead of a hang
    for _ in range(64):
        if eng.metrics().degraded_mode == 0:
            break
        eng.tick()
    m = eng.metrics()
    reqs = list(eng.sched.all_requests())
    evicted = {
        r.rid for r in reqs
        if r.done_reason not in (None, "eos", "length")
    }
    all_served = all(
        r.done_reason in ("eos", "length") and len(r.output) > 0
        for r in reqs if r.rid not in evicted
    )
    faulted = {
        "accounting": m.analog,
        "degraded_mode_final": m.degraded_mode,
        "degraded_mode_max": max(
            [t["to"] for t in m.degraded_transitions], default=0
        ),
        "canary_probes": m.canary_probes,
        "canary_failures": m.canary_failures,
        "retired_tiles": m.retired_tiles,
        "redundant_read_events": m.redundant_read_events,
        "transitions": m.degraded_transitions,
        "evictions": dict(m.evictions),
        "all_served": all_served,
        "injected": [(t, k) for t, k, _ in inj.applied],
    }
    return {
        "n_requests": n_req,
        "stuck_rate": stuck_rate,
        "canary_interval": 1,
        "zero_fault": zero_fault,
        "faulted": faulted,
    }


def run(dry_run: bool = False) -> tuple[list[tuple[str, float, str]], dict]:
    base = get_smoke_config("stablelm-3b")
    if dry_run:
        cfg = base
        trace_kw = dict(
            seed=0, n_req=4, mean_gap_ticks=1.0,
            prompt_len_range=(2, 8), new_tokens_range=(2, 6),
        )
        serve_cfg = ServeConfig(max_batch=2, max_new_tokens=8, max_len=64)
    else:
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
            d_head=32, max_seq=256,
        )
        trace_kw = dict(
            seed=0, n_req=16, mean_gap_ticks=1.5,
            prompt_len_range=(3, 25), new_tokens_range=(4, 17),
        )
        serve_cfg = ServeConfig(max_batch=4, max_new_tokens=16, max_len=128)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    trace = make_trace(vocab=cfg.vocab, **trace_kw)

    rows: list[tuple[str, float, str]] = []
    report: dict = {"engines": {}, "paged_vs_dense": []}
    # continuous batching, digital argmax baseline
    m_greedy = _bench(
        dataclasses.replace(cfg, wta_head=False), params, trace, serve_cfg
    )
    rows.append(("serve_cb_greedy", m_greedy.wall_time * 1e6, m_greedy.row()))
    report["engines"]["cb_greedy_paged"] = _metrics_dict(m_greedy)
    # continuous batching, WTA stochastic-SoftMax head (paper sampler)
    for trials in (8, 32) if not dry_run else (8,):
        cfg_w = dataclasses.replace(
            cfg, wta_head=True,
            analog=dataclasses.replace(cfg.analog, wta_trials=trials),
        )
        m_wta = _bench(cfg_w, params, trace, serve_cfg)
        rows.append(
            (f"serve_cb_wta_T{trials}", m_wta.wall_time * 1e6, m_wta.row())
        )
        report["engines"][f"cb_wta_T{trials}"] = _metrics_dict(m_wta)
    # static-batch reference on the same trace
    stat = StaticServingEngine(
        params, dataclasses.replace(cfg, wta_head=False), serve_cfg
    )
    drive_static(stat, trace)
    m_stat = stat.metrics()
    rows.append(("serve_static_greedy", m_stat.wall_time * 1e6, m_stat.row()))
    report["engines"]["static_greedy_dense"] = _metrics_dict(m_stat)
    rows.append(
        (
            "serve_occupancy_gain",
            0.0,
            f"continuous={m_greedy.occupancy_mean:.2f} "
            f"static={m_stat.occupancy_mean:.2f} "
            f"gain={m_greedy.occupancy_mean - m_stat.occupancy_mean:+.2f}",
        )
    )

    # paged-vs-dense decode latency across max_len (the perf trajectory the
    # CI artifact tracks).  Always the 4-layer bench model: the smoke model
    # is too small for decode cost to rise above dispatch overhead.
    pvd_cfg = dataclasses.replace(
        base, n_layers=4, d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
        d_head=32, max_seq=1024, wta_head=False,
    )
    pvd_params = get_model_fns(pvd_cfg).init(jax.random.PRNGKey(0), pvd_cfg)
    for ml in (128, 512):
        res = bench_paged_vs_dense(
            pvd_cfg, pvd_params, max_len=ml, n_req=6 if dry_run else 16
        )
        report["paged_vs_dense"].append(res)
        rows.append(
            (
                f"serve_paged_vs_dense_L{ml}",
                res["paged"]["decode_step_ms"] * 1e3,
                f"dense_ms={res['dense']['decode_step_ms']:.2f} "
                f"paged_ms={res['paged']['decode_step_ms']:.2f} "
                f"speedup={res['decode_speedup']:.2f}x "
                f"occ_dense={res['dense']['occupancy']:.2f} "
                f"occ_paged={res['paged']['occupancy']:.2f}",
            )
        )
    # int8 vs bf16 paged pool: decode latency + throughput at the same
    # max_len points, and the equal-memory admission capacity sweep
    report["paged_int8_vs_bf16"] = []
    for ml in (128, 512):
        res = bench_paged_int8(
            pvd_cfg, pvd_params, max_len=ml, n_req=6 if dry_run else 16
        )
        report["paged_int8_vs_bf16"].append(res)
        rows.append(
            (
                f"serve_paged_int8_L{ml}",
                res["int8"]["decode_step_ms"] * 1e3,
                f"bf16_ms={res['bf16']['decode_step_ms']:.2f} "
                f"int8_ms={res['int8']['decode_step_ms']:.2f} "
                f"speedup={res['decode_speedup']:.2f}x "
                f"tok_s_ratio={res['tokens_per_s_ratio']:.2f}x",
            )
        )
    cap = bench_int8_capacity(pvd_cfg, pvd_params)
    report["int8_capacity_sweep"] = cap
    rows.append(
        (
            "serve_int8_capacity",
            0.0,
            f"blocks={cap['num_kv_blocks']} "
            f"admitted_bf16={cap['admitted_bf16']} "
            f"admitted_int8={cap['admitted_int8']} "
            f"ratio={cap['capacity_ratio']:.2f}x",
        )
    )
    # prefix sharing on a repeated-prefix trace: prefill FLOPs saved +
    # admission capacity at equal num_kv_blocks, with byte-identity checked
    pfx = bench_prefix_sharing(
        pvd_cfg, pvd_params, n_req=6 if dry_run else 12
    )
    report["prefix_sharing"] = pfx
    rows.append(
        (
            "serve_prefix_sharing",
            0.0,
            f"prefills={pfx['off']['prefills']}->{pfx['on']['prefills']} "
            f"savings={pfx['prefill_savings']:.2f} "
            f"admitted={pfx['admitted_off']}->{pfx['admitted_on']} "
            f"capacity={pfx['capacity_ratio']:.2f}x "
            f"match={pfx['tokens_match']}",
        )
    )
    # suffix-only prefill on the shared-system-prompt trace: computed
    # prefill tokens + late-arrival TTFT with chunked interleaved prefill
    par = bench_partial_prefix(
        pvd_cfg, pvd_params, n_req=6 if dry_run else 10
    )
    report["partial_prefix"] = par
    rows.append(
        (
            "serve_partial_prefix",
            0.0,
            f"prefill_tokens={par['off']['prefill_tokens']}"
            f"->{par['on']['prefill_tokens']} "
            f"reduction={par['prefill_token_reduction']:.2f}x "
            f"partial_hits={par['on']['prefix_partial_hits']} "
            f"late_ttft={par['off']['late_ttft_ms']:.1f}"
            f"->{par['on']['late_ttft_ms']:.1f}ms "
            f"match={par['tokens_match']}",
        )
    )
    # preemptive scheduling on a bursty two-class trace: interactive tail
    # latency with spill/restore preemption on vs off, identity enforced
    pre = bench_preemption(
        pvd_cfg, pvd_params, n_each=2 if dry_run else 3
    )
    report["preemption"] = pre
    rows.append(
        (
            "serve_preemption",
            0.0,
            f"preempt={pre['on']['preemptions']} "
            f"restore={pre['on']['restores']} "
            f"inter_p99="
            f"{pre['off']['interactive'].get('ttft_p99_ms', 0):.1f}"
            f"->{pre['on']['interactive'].get('ttft_p99_ms', 0):.1f}ms "
            f"ratio={pre['interactive_p99_ratio']:.2f} "
            f"match={pre['tokens_match']}",
        )
    )
    # self-speculative decoding: draft-k + one-dispatch verify vs plain
    # decode on the same trace, byte-identity + tokens/s floor enforced.
    # Run on the dispatch-bound smoke config: the draft run repeats the
    # same model math as plain steps, so the measurable win is per-tick
    # host/dispatch amortization — on the compute-bound 4-layer model the
    # ratio pins to ~1.0 and the floor check would only measure noise
    spec_params = params if cfg is base else get_model_fns(base).init(
        jax.random.PRNGKey(0), base
    )
    # full-length trace even under --dry-run: the enforced ratio needs
    # enough steady-state tokens that scheduler jitter can't flip it
    spd = bench_speculative(base, spec_params, n_req=10)
    report["speculative_decode"] = spd
    rows.append(
        (
            "serve_speculative_decode",
            0.0,
            f"k={spd['speculate_k']} acc={spd['acceptance']:.2f} "
            f"tok_per_round={spd['tokens_per_round']:.2f} "
            f"tok_s={spd['plain']['tokens_per_s']:.1f}"
            f"->{spd['spec']['tokens_per_s']:.1f} "
            f"ratio={spd['tokens_per_s_ratio']:.2f}x "
            f"match={spd['tokens_match']}",
        )
    )
    # energy-per-token accounting under Table I pricing (RACA vs 1b-ADC),
    # count reconciliation + the RACA-cheaper inequality enforced by
    # validate_report on the committed artifact
    ept = bench_energy_per_token(
        pvd_cfg, pvd_params, n_req=4 if dry_run else 8
    )
    report["energy_per_token"] = ept
    rows.append(
        (
            "serve_energy_per_token",
            ept["raca_energy_pj_per_token"],
            f"raca={ept['raca_energy_pj_per_token']:.0f}pJ/tok "
            f"adc1b={ept['adc1b_energy_pj_per_token']:.0f}pJ/tok "
            f"raca_tops_w={ept['raca_tops_per_w']:.2f} "
            f"spec_overhead={ept['speculative']['overhead_ratio']:.2f}x "
            f"match={ept['speculative']['tokens_match']}",
        )
    )
    # fault tolerance: zero-knob identity vs sim, then the injected
    # device-fault loop (canary detect -> tile retirement -> degradation
    # ladder -> recovery), reconciled + reversibility-checked downstream
    ft = bench_fault_tolerance(
        pvd_cfg, pvd_params, n_req=4 if dry_run else 8
    )
    report["fault_tolerance"] = ft
    fa = ft["faulted"]
    rows.append(
        (
            "serve_fault_tolerance",
            0.0,
            f"zero_match={ft['zero_fault']['tokens_match']} "
            f"canary={fa['canary_failures']}/{fa['canary_probes']} "
            f"retired={fa['retired_tiles']} "
            f"redundant={fa['redundant_read_events']} "
            f"ladder_max={fa['degraded_mode_max']}"
            f"->final={fa['degraded_mode_final']} "
            f"served={fa['all_served']}",
        )
    )
    # sharded paged decode over the local host mesh: token identity vs the
    # single-device engine + admission capacity scaling with the data axis
    shd = bench_sharded_decode(
        pvd_cfg, pvd_params, n_req=6 if dry_run else 8
    )
    report["sharded_decode"] = shd
    rows.append(
        (
            "serve_sharded_decode",
            0.0,
            f"mesh=({shd['mesh']['data']},{shd['mesh']['model']}) "
            f"admitted={shd['admitted_single']}->{shd['admitted_sharded']} "
            f"capacity={shd['capacity_ratio']:.2f}x "
            f"match={shd['tokens_match']}",
        )
    )
    return rows, report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="tiny trace on the smoke model (CI smoke)",
    )
    ap.add_argument(
        "--out", default="BENCH_serving.json",
        help="where to write the machine-readable report",
    )
    ap.add_argument(
        "--validate", metavar="PATH",
        help="validate an existing report against the published schema "
             "and exit (the CI artifact check)",
    )
    args = ap.parse_args()
    if args.validate:
        with open(args.validate) as f:
            validate_report(json.load(f))
        print(f"{args.validate}: schema OK")
        return
    rows, report = run(dry_run=args.dry_run)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    report["dry_run"] = args.dry_run
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    # round-trip the written artifact through the schema check: if the
    # report can no longer parse as its own published schema, fail the run
    # (and therefore CI) loudly instead of shipping a broken artifact
    with open(args.out) as f:
        validate_report(json.load(f))
    print(f"wrote {args.out} (schema OK)")


if __name__ == "__main__":
    main()
