"""Serving-side RACA: decode throughput, greedy vs WTA stochastic sampling.

The paper's repeated-trial voting (Fig. 6) applied to LM decoding: each
token is chosen by T comparator-bank decision trials.  This benchmark
quantifies the sampler's cost (compare-and-count per trial; no
exponentials) against digital greedy argmax on the same model, and the
vote-count sensitivity.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs import get_smoke_config
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine


def _throughput(cfg, params, n_req=4, new_tokens=12):
    eng = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=n_req, max_new_tokens=new_tokens, max_len=128),
    )
    for i in range(n_req):
        eng.submit([7 + i, 11, 13])
    t0 = time.perf_counter()
    outs = eng.step()
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    return toks / dt, dt * 1e6


def run() -> list[tuple[str, float, str]]:
    base = get_smoke_config("stablelm-3b")
    cfg = dataclasses.replace(
        base, n_layers=4, d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
        d_head=32, max_seq=256,
    )
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)

    rows = []
    tps, us = _throughput(dataclasses.replace(cfg, wta_head=False), params)
    rows.append(("serve_greedy", us, f"tok_per_s={tps:.1f}"))
    for trials in (8, 32):
        cfg_w = dataclasses.replace(
            cfg, wta_head=True,
            analog=dataclasses.replace(cfg.analog, wta_trials=trials),
        )
        tps, us = _throughput(cfg_w, params)
        rows.append(
            (f"serve_wta_T{trials}", us, f"tok_per_s={tps:.1f}")
        )
    return rows
