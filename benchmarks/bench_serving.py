"""Serving-side RACA under load: continuous batching vs static batching,
greedy vs WTA stochastic sampling.

A Poisson-ish arrival trace (exponential inter-arrival gaps measured in
decode-step ticks, mixed prompt lengths, mixed per-request token budgets)
drives the continuous-batching engine; the same trace drives the static
reference.  Reported per engine/sampler: tokens/s, mean time-to-first-token
and mean slot occupancy.  The headline system-level claim: on mixed-length
traffic the scheduler's mid-flight slot refill keeps occupancy above the
static baseline, and the WTA vote sampler (paper §III-B/C, Fig. 6) rides
along at full batch width with per-slot PRNG streams.

    PYTHONPATH=src python -m benchmarks.bench_serving [--dry-run]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine, StaticServingEngine


def make_trace(
    seed: int,
    n_req: int,
    mean_gap_ticks: float,
    prompt_len_range: tuple[int, int],
    new_tokens_range: tuple[int, int],
    vocab: int,
) -> list[tuple[int, list[int], int]]:
    """(arrival_tick, prompt, max_new_tokens) rows, arrival-sorted.

    Arrivals are a Poisson-ish process over engine ticks (exponential gaps)
    rather than wall clock, so the trace is deterministic for a seed and
    independent of host speed.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    trace = []
    for _ in range(n_req):
        t += rng.exponential(mean_gap_ticks)
        plen = int(rng.integers(*prompt_len_range))
        prompt = rng.integers(1, vocab, size=plen).tolist()
        budget = int(rng.integers(*new_tokens_range))
        trace.append((int(t), prompt, budget))
    return trace


def drive_continuous(engine: ServingEngine, trace) -> None:
    """Feed the trace by tick index; drain after the last arrival."""
    i, tick = 0, 0
    while i < len(trace) or engine.sched.has_work():
        while i < len(trace) and trace[i][0] <= tick:
            _, prompt, budget = trace[i]
            engine.submit(prompt, budget)
            i += 1
        engine.tick()
        tick += 1


def drive_static(engine: StaticServingEngine, trace) -> None:
    """Feed the same tick-indexed trace to the static engine.

    Static batching cannot admit mid-flight: each ``step()`` wave consumes
    as many ticks as it ran decode steps, and requests arriving during a
    wave wait in the queue — the TTFT / occupancy cost being measured.
    Requests whose arrival tick fell inside a finished wave are submitted
    with a backdated timestamp (measured seconds/tick), so their queue wait
    counts toward static TTFT just as it does for the continuous engine.
    """
    i, tick = 0, 0
    tick_wall = time.perf_counter()
    sec_per_tick = 0.0
    while i < len(trace) or engine.pending():
        while i < len(trace) and trace[i][0] <= tick:
            _, prompt, budget = trace[i]
            arrival_wall = tick_wall - (tick - trace[i][0]) * sec_per_tick
            engine.submit(prompt, budget, submit_time=arrival_wall)
            i += 1
        if engine.pending():
            before = engine.metrics().decode_steps
            t0 = time.perf_counter()
            engine.step()
            steps = max(engine.metrics().decode_steps - before, 1)
            sec_per_tick = (time.perf_counter() - t0) / steps
            tick += steps
        else:
            tick += 1
        tick_wall = time.perf_counter()


def _bench(cfg, params, trace, serve_cfg):
    eng = ServingEngine(params, cfg, serve_cfg)
    drive_continuous(eng, trace)
    return eng.metrics()


def run(dry_run: bool = False) -> list[tuple[str, float, str]]:
    base = get_smoke_config("stablelm-3b")
    if dry_run:
        cfg = base
        trace_kw = dict(
            seed=0, n_req=4, mean_gap_ticks=1.0,
            prompt_len_range=(2, 8), new_tokens_range=(2, 6),
        )
        serve_cfg = ServeConfig(max_batch=2, max_new_tokens=8, max_len=64)
    else:
        cfg = dataclasses.replace(
            base, n_layers=4, d_model=128, d_ff=256, n_heads=4, n_kv_heads=4,
            d_head=32, max_seq=256,
        )
        trace_kw = dict(
            seed=0, n_req=16, mean_gap_ticks=1.5,
            prompt_len_range=(3, 25), new_tokens_range=(4, 17),
        )
        serve_cfg = ServeConfig(max_batch=4, max_new_tokens=16, max_len=128)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    trace = make_trace(vocab=cfg.vocab, **trace_kw)

    rows = []
    # continuous batching, digital argmax baseline
    m_greedy = _bench(
        dataclasses.replace(cfg, wta_head=False), params, trace, serve_cfg
    )
    rows.append(("serve_cb_greedy", m_greedy.wall_time * 1e6, m_greedy.row()))
    # continuous batching, WTA stochastic-SoftMax head (paper sampler)
    for trials in (8, 32) if not dry_run else (8,):
        cfg_w = dataclasses.replace(
            cfg, wta_head=True,
            analog=dataclasses.replace(cfg.analog, wta_trials=trials),
        )
        m_wta = _bench(cfg_w, params, trace, serve_cfg)
        rows.append(
            (f"serve_cb_wta_T{trials}", m_wta.wall_time * 1e6, m_wta.row())
        )
    # static-batch reference on the same trace
    stat = StaticServingEngine(
        params, dataclasses.replace(cfg, wta_head=False), serve_cfg
    )
    drive_static(stat, trace)
    m_stat = stat.metrics()
    rows.append(("serve_static_greedy", m_stat.wall_time * 1e6, m_stat.row()))
    rows.append(
        (
            "serve_occupancy_gain",
            0.0,
            f"continuous={m_greedy.occupancy_mean:.2f} "
            f"static={m_stat.occupancy_mean:.2f} "
            f"gain={m_greedy.occupancy_mean - m_stat.occupancy_mean:+.2f}",
        )
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--dry-run", action="store_true",
        help="tiny trace on the smoke model (CI smoke)",
    )
    args = ap.parse_args()
    for name, us, derived in run(dry_run=args.dry_run):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
