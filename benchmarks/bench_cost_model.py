"""Table I reproduction: hardware metrics of the 1-bit-ADC design vs RACA."""

from __future__ import annotations

from repro.core import cost_model as CM


def run() -> list[tuple[str, float, str]]:
    t = CM.table1()
    a, r = t["adc1b"], t["raca"]
    rows = [
        ("table1_adc1b", 0.0,
         f"E={a.energy_pj:.3e}pJ A={a.area_mm2:.2f}mm2 "
         f"eff={a.tops_per_w:.1f}TOPS/W"),
        ("table1_raca", 0.0,
         f"E={r.energy_pj:.3e}pJ A={r.area_mm2:.2f}mm2 "
         f"eff={r.tops_per_w:.1f}TOPS/W"),
        ("table1_changes", 0.0,
         f"energy{t['energy_change_pct']:+.2f}% "
         f"area{t['area_change_pct']:+.2f}% "
         f"eff{t['efficiency_change_pct']:+.2f}% "
         "(paper: -58.29/-38.43/+142.37)"),
    ]
    return rows
