"""Fig. 4 reproduction: stochastic Sigmoid neuron fidelity vs the four SNR
knobs (V_r, G0 via conductance range, Δf, N_col), plus kernel timing.

Reports, per knob setting, the RMS error between the comparator's fire
probability and the ideal logistic — the quantitative version of the
paper's Fig. 4(c)-(f) overlay plots.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crossbar, neurons, physics


def _fit_rmse(dp: physics.DeviceParams, n_rows: int) -> float:
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_rows, 128)) * (2.0 / n_rows) ** 0.5 * 4
    x = (jax.random.uniform(jax.random.PRNGKey(1), (256, n_rows)) < 0.3)
    m = crossbar.map_weights(w, dp)
    z = x.astype(jnp.float32) @ m.w_eff
    p = neurons.fire_probability_physical(z, crossbar.column_sum_g(m), dp)
    return float(jnp.sqrt(jnp.mean((p - jax.nn.sigmoid(z)) ** 2)))


def run() -> list[tuple[str, float, str]]:
    rows = []
    n0 = 784
    cal = physics.calibrate_v_read(physics.DeviceParams(), n0)

    t0 = time.perf_counter()
    base = _fit_rmse(cal, n0)
    dt_us = (time.perf_counter() - t0) * 1e6
    rows.append(("sigmoid_fit_calibrated", dt_us, f"rmse={base:.4f}"))

    # Fig 4(c): read-voltage sweep — ±2x detunes the logistic slope
    for f in (0.5, 2.0):
        r = _fit_rmse(cal.replace(v_read=cal.v_read * f), n0)
        rows.append((f"sigmoid_fit_vr_x{f}", 0.0, f"rmse={r:.4f}"))
    # Fig 4(d): G0 sweep via conductance range
    r = _fit_rmse(
        physics.calibrate_v_read(
            physics.DeviceParams(g_max=2e-4), n0
        ),
        n0,
    )
    rows.append(("sigmoid_fit_g0_recal", 0.0, f"rmse={r:.4f}"))
    # Fig 4(e): bandwidth sweep (recalibrated -> fit restored)
    r = _fit_rmse(
        physics.calibrate_v_read(
            physics.DeviceParams(delta_f=4e9), n0
        ),
        n0,
    )
    rows.append(("sigmoid_fit_df_recal", 0.0, f"rmse={r:.4f}"))
    # Fig 4(f): column length sweep
    for n in (256, 1568):
        r = _fit_rmse(physics.calibrate_v_read(physics.DeviceParams(), n), n)
        rows.append((f"sigmoid_fit_ncol_{n}", 0.0, f"rmse={r:.4f}"))

    # detuned (uncalibrated) should be clearly worse than calibrated
    r_detuned = _fit_rmse(cal.replace(v_read=cal.v_read * 4), n0)
    rows.append(
        ("sigmoid_fit_detuned_x4", 0.0,
         f"rmse={r_detuned:.4f} (vs {base:.4f} calibrated)")
    )
    return rows
