"""Fig. 5 reproduction: WTA stochastic SoftMax neuron statistics.

Measures (a) one-winner-per-trial, (b) TV distance of the cumulative vote
distribution vs the ideal SoftMax as trials grow, (c) argmax agreement —
the quantitative content of Fig. 5(a)-(d).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import wta


def run() -> list[tuple[str, float, str]]:
    rows = []
    z = jax.random.normal(jax.random.PRNGKey(3), (10,))
    sm = jax.nn.softmax(z)
    theta = wta.calibrated_threshold()

    t0 = time.perf_counter()
    res = wta.wta_trials(jax.random.PRNGKey(0), z, 100, theta)
    dt = (time.perf_counter() - t0) * 1e6
    one_winner = float(res.counts.sum()) == float(res.n_decisions)
    rows.append(
        ("wta_100_trials", dt,
         f"one_winner_per_trial={one_winner} "
         f"decision_rate={float(res.n_decisions) / 100:.2f}")
    )

    for t in (100, 1000, 10000, 40000):
        res = wta.wta_trials(jax.random.PRNGKey(1), z, t, theta)
        tv = 0.5 * float(jnp.abs(res.probs - sm).sum())
        agree = int(jnp.argmax(res.probs)) == int(jnp.argmax(sm))
        rows.append(
            (f"wta_tv_vs_softmax_T{t}", 0.0,
             f"tv={tv:.4f} argmax_agree={agree}")
        )

    ana = wta.wta_expected_probs(z, theta)
    tv_ana = 0.5 * float(jnp.abs(ana - sm).sum())
    rows.append(("wta_analytic_vs_softmax", 0.0, f"tv={tv_ana:.4f}"))
    return rows
