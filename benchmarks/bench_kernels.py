"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU latency —
the derived columns report the roofline-relevant bytes/FLOPs per call).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.kernels import ops


def _time(f, *a, n=3):
    f(*a)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[tuple[str, float, str]]:
    rows = []
    cfg = AnalogConfig(
        mode="analog_stochastic",
        device=calibrate_v_read(DeviceParams(), 1024),
        use_pallas="off",  # jnp reference path for timing on CPU
    )
    key = jax.random.PRNGKey(0)

    for m, k, n in [(256, 1024, 512), (1024, 4096, 1024)]:
        x = jax.random.normal(key, (m, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
        f = jax.jit(
            lambda x, w: ops.crossbar_mac_reference(x, w, key, cfg, True)
        )
        us = _time(f, x, w)
        flops = 2 * m * k * n
        rows.append(
            (f"crossbar_mac_{m}x{k}x{n}", us,
             f"flops={flops:.2e} tpu_roofline_us={flops / 197e6:.1f}")
        )

    z = jax.random.normal(key, (256, 128))
    f = jax.jit(
        lambda z: ops.wta_counts_reference(
            z, key, n_trials=64, vth0=2.897, sigma_z=1.702
        )
    )
    us = _time(f, z)
    rows.append(("wta_counts_256x128_T64", us,
                 f"bytes={256 * 128 * 4 * 64:.2e}"))

    x = jax.random.normal(key, (2048, 2048))
    f = jax.jit(
        lambda x: ops.stoch_round_reference(x, key, step=2 / 31, lo=-1, hi=1)
    )
    us = _time(f, x)
    rows.append(
        ("stoch_round_2048x2048", us,
         f"bytes={2048 * 2048 * 8:.2e} tpu_bw_us={2048 * 2048 * 8 / 819e3:.1f}")
    )
    return rows
