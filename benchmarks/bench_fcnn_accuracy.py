"""Fig. 6 reproduction: RACA inference accuracy vs number of WTA votes,
for SNR (Fig. 6a) and threshold-voltage (Fig. 6b) sweeps.

Trains the paper's FCNN (reduced hidden widths for container runtime;
examples/train_mnist_raca.py runs the full [784,500,300,10]) with the
stochastic-binary STE recipe on the MNIST surrogate, then measures:
  * digital-baseline accuracy (exact sigmoid + argmax),
  * stochastic RACA accuracy at 1/4/16/64 votes,
  * the same under detuned SNR (Fig. 6a) and V_th0 ∈ {0, calibrated}
    (Fig. 6b).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs.fcnn_mnist import CONFIG as FCNN_CFG
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.data import mnist_batch, mnist_dataset
from repro.models.fcnn import fcnn_predict_digital, fcnn_predict_raca
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step

LAYERS = (784, 128, 64, 10)
TRAIN_STEPS = 400


def _train(cfg):
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=5e-3, state_dtype="float32",
                        stochastic_rounding=False)
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    for i in range(TRAIN_STEPS):
        state, _ = step(state, mnist_batch(batch=128, step=i))
    return state.params


def run() -> list[tuple[str, float, str]]:
    rows = []
    cfg = dataclasses.replace(
        FCNN_CFG,
        fcnn_layers=LAYERS,
        analog=dataclasses.replace(
            FCNN_CFG.analog,
            device=calibrate_v_read(DeviceParams(), LAYERS[0]),
            use_pallas="off",
        ),
    )
    t0 = time.perf_counter()
    params = _train(cfg)
    train_us = (time.perf_counter() - t0) * 1e6
    test = mnist_dataset(512)
    x, y = test["image"], np.asarray(test["label"])

    digital = float((np.asarray(fcnn_predict_digital(params, x, cfg)) == y).mean())
    rows.append(("fcnn_train", train_us, f"digital_acc={digital:.4f}"))

    for votes in (1, 4, 16, 64):
        t0 = time.perf_counter()
        pred = fcnn_predict_raca(
            params, x, cfg, jax.random.PRNGKey(7), votes
        )
        dt = (time.perf_counter() - t0) * 1e6
        acc = float((np.asarray(pred) == y).mean())
        rows.append((f"fig6_raca_votes{votes}", dt, f"acc={acc:.4f}"))

    # Fig 6(b): threshold sweep at 16 votes
    for name, vth in (("vth0_zero", 0.0), ("vth0_cal", None)):
        pred = fcnn_predict_raca(
            params, x, cfg, jax.random.PRNGKey(8), 16, vth0=vth
        )
        acc = float((np.asarray(pred) == y).mean())
        rows.append((f"fig6b_{name}_votes16", 0.0, f"acc={acc:.4f}"))

    # Fig 6(a): detuned SNR (β=2 — sharper, undertrained mismatch)
    cfg_det = dataclasses.replace(
        cfg,
        analog=dataclasses.replace(cfg.analog, beta=2.0),
    )
    pred = fcnn_predict_raca(
        params, x, cfg_det, jax.random.PRNGKey(9), 16
    )
    acc = float((np.asarray(pred) == y).mean())
    rows.append(("fig6a_detuned_beta2_votes16", 0.0, f"acc={acc:.4f}"))
    return rows
