"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_sigmoid        — Fig. 4 (sigmoid-neuron fidelity vs SNR knobs)
  bench_wta            — Fig. 5 (WTA vote statistics vs softmax)
  bench_fcnn_accuracy  — Fig. 6 (accuracy vs votes / threshold / SNR)
  bench_cost_model     — Table I (energy / area / TOPS-W)
  bench_kernels        — kernel micro-bench + roofline-relevant derived
  bench_serving        — WTA-vote vs greedy decode throughput
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_cost_model,
        bench_fcnn_accuracy,
        bench_kernels,
        bench_serving,
        bench_sigmoid,
        bench_wta,
    )

    mods = [
        ("fig4", bench_sigmoid),
        ("fig5", bench_wta),
        ("fig6", bench_fcnn_accuracy),
        ("table1", bench_cost_model),
        ("kernels", bench_kernels),
        ("serving", bench_serving),
    ]
    print("name,us_per_call,derived")
    failed = False
    for tag, mod in mods:
        try:
            out = mod.run()
            # bench_serving returns (rows, machine-readable report)
            rows = out[0] if isinstance(out, tuple) else out
            for name, us, derived in rows:
                print(f"{tag}/{name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception:
            failed = True
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
