"""Shared test/chaos utilities usable from production code paths.

Fault injection lives here so the training loop and the serving engine
drive ONE mechanism instead of two ad-hoc ones: the primitives are pure
host logic with no JAX imports, cheap enough to stay compiled into
production builds (an un-armed injector is a dict lookup per tick).
"""

from .faults import (
    FaultEvent,
    FaultSchedule,
    InjectedFault,
    StepFaultInjector,
    fault_step_from_env,
)

__all__ = [
    "FaultEvent",
    "FaultSchedule",
    "InjectedFault",
    "StepFaultInjector",
    "fault_step_from_env",
]
