"""Fault-injection primitives shared by the train loop and serving engine.

Two shapes of injection exist in this repo and both are built from the
pieces here:

  * the TRAIN loop wants "raise once at step N" — :class:`StepFaultInjector`
    wraps the arm/fire-exactly-once bookkeeping, :func:`fault_step_from_env`
    keeps the historical ``FAULT_INJECT_STEP`` env interface, and
    :class:`InjectedFault` is the exception the loop's retry path catches;
  * the SERVING engine wants "at tick N, do X with these args" for several
    X — :class:`FaultSchedule` maps ticks to :class:`FaultEvent` lists and
    the engine-specific interpreter (``repro.serving.faults``) gives each
    event kind its meaning.

Everything here is pure host logic (no JAX): an un-armed injector costs a
``None`` check or an empty-dict lookup per step, so production code can
thread it unconditionally.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional


class InjectedFault(RuntimeError):
    """A deliberately injected failure — never raised by real faults, so
    retry paths can catch it precisely without masking genuine errors."""


def fault_step_from_env(
    explicit: Optional[int], env: str = "FAULT_INJECT_STEP"
) -> Optional[int]:
    """Resolve a fault step: an explicit config value wins, else ``env``.

    The env fallback is what lets operators arm a fault on a deployed
    binary without a config change — the interface the train-loop tests
    pin.
    """
    if explicit is not None:
        return int(explicit)
    raw = os.environ.get(env)
    return int(raw) if raw else None


class StepFaultInjector:
    """Raise :class:`InjectedFault` exactly once when ``step`` is reached.

    ``check(step)`` is called once per loop iteration; after firing the
    injector disarms itself, so the retry that resumes past the fault
    step does not re-trip it.  ``step=None`` never fires.
    """

    def __init__(self, step: Optional[int]):
        self.step = step
        self.fired = False

    @property
    def armed(self) -> bool:
        return self.step is not None and not self.fired

    def check(self, step: int) -> None:
        if self.armed and step == self.step:
            self.fired = True
            raise InjectedFault(f"injected fault at step {step}")


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault: fire ``kind`` at ``tick`` with ``kwargs``.

    ``kind`` is interpreted by whoever drains the schedule (the serving
    engine's injector defines ``exhaust_pool``/``nan_logits``/...); this
    module only carries the timetable.
    """

    tick: int
    kind: str
    kwargs: dict[str, Any] = dataclasses.field(default_factory=dict)


class FaultSchedule:
    """A tick-indexed timetable of :class:`FaultEvent`\\ s.

    Built by chaining ``.at(tick, kind, **kwargs)``; the driven system
    calls ``pop(tick)`` once per tick and interprets whatever events come
    back.  Events fire exactly once (popping removes them) and ``fired``
    accumulates the history for test assertions.
    """

    def __init__(self) -> None:
        self._events: dict[int, list[FaultEvent]] = {}
        self.fired: list[FaultEvent] = []

    def at(self, tick: int, kind: str, **kwargs: Any) -> "FaultSchedule":
        self._events.setdefault(int(tick), []).append(
            FaultEvent(int(tick), str(kind), kwargs)
        )
        return self

    def pop(self, tick: int) -> list[FaultEvent]:
        events = self._events.pop(int(tick), [])
        self.fired.extend(events)
        return events

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._events.values())

    def __bool__(self) -> bool:
        return bool(self._events)
