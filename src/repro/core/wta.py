"""WTA binary stochastic SoftMax neurons (paper §III-B, Eq. 14).

Per decision trial every output neuron's noisy voltage V_j (proportional to
I_j - I_ref) is compared against an adaptive threshold resting at V_th0.
When one neuron crosses, the threshold is pulled to supply and suppresses the
rest — so at most one winner per trial (Fig. 5(a)); physically the winner is
the neuron furthest above threshold (the race is won by the largest drive).
Counting winners over T trials yields a cumulative distribution (Fig. 5(c))
that approximates SoftMax:

    P_WTA(y_j = 1) = P(y_j=1)/Σ_k P(y_k=1) ~= e^{z_j} / Σ_k e^{z_k}   (Eq. 14)

The Gaussian-tail argument fixes the operating point: with per-neuron voltage
V_j = z_j + n, n ~ N(0, σ²) (z-units after calibration),

    P(V_j > θ) ∝ exp(z_j·θ/σ² - z_j²/2σ²)   for θ >> |z_j|,

so θ = σ² gives unit softmax temperature; θ ("V_th0") too small degrades the
approximation, too large stretches decision time — exactly the paper's §IV-C
trade-off (Fig. 6(b)).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .physics import DeviceParams, PROBIT_SCALE


class WTAResult(NamedTuple):
    counts: jax.Array        # (..., C) winner counts over trials
    n_decisions: jax.Array   # (...,)   trials with >=1 neuron fired
    probs: jax.Array         # (..., C) normalized cumulative distribution


def wta_sigma_z(beta: float = 1.0) -> float:
    """Noise std in z-units at the calibrated operating point."""
    return PROBIT_SCALE / beta


def calibrated_threshold(beta: float = 1.0, temp: float = 1.0) -> float:
    """θ = σ²/temp gives softmax with temperature ``temp`` (tail argument)."""
    s = wta_sigma_z(beta)
    return s * s / temp


def wta_trials(
    key: jax.Array,
    z: jax.Array,
    n_trials: int,
    vth0: float,
    sigma_z: float | None = None,
    beta: float = 1.0,
) -> WTAResult:
    """Simulate T WTA decision trials on pre-activations ``z`` (..., C).

    Vectorized over trials: each trial draws independent thermal noise for
    every neuron, fires the set {V_j > vth0}, and the largest-drive firing
    neuron wins the threshold race.  Returns winner counts and normalized
    probabilities (the counter of §III-C).
    """
    if sigma_z is None:
        sigma_z = wta_sigma_z(beta)
    noise = (
        jax.random.normal(key, (n_trials,) + z.shape, dtype=jnp.float32)
        * sigma_z
    )
    v = z[None, ...] + noise                      # (T, ..., C)
    fired = v > vth0                              # comparator bank
    any_fired = jnp.any(fired, axis=-1)           # (T, ...)
    # Winner: argmax over fired neurons' voltages (race to pull threshold up).
    neg_inf = jnp.finfo(jnp.float32).min
    v_masked = jnp.where(fired, v, neg_inf)
    winner = jnp.argmax(v_masked, axis=-1)        # (T, ...)
    onehot = jax.nn.one_hot(winner, z.shape[-1], dtype=jnp.float32)
    onehot = onehot * any_fired[..., None].astype(jnp.float32)
    counts = onehot.sum(axis=0)                   # (..., C)
    n_dec = any_fired.sum(axis=0).astype(jnp.float32)
    probs = counts / jnp.maximum(counts.sum(axis=-1, keepdims=True), 1.0)
    return WTAResult(counts=counts, n_decisions=n_dec, probs=probs)


def wta_classify(
    key: jax.Array,
    z: jax.Array,
    n_trials: int,
    vth0: float,
    sigma_z: float | None = None,
    beta: float = 1.0,
) -> jax.Array:
    """Majority-vote classification: argmax of cumulative winner counts."""
    res = wta_trials(key, z, n_trials, vth0, sigma_z, beta)
    return jnp.argmax(res.counts, axis=-1)


def wta_fire_probability(
    z: jax.Array, vth0: float, sigma_z: float | None = None, beta: float = 1.0
) -> jax.Array:
    """Per-neuron single-trial fire probability P(V_j > vth0)."""
    if sigma_z is None:
        sigma_z = wta_sigma_z(beta)
    return 0.5 * (
        1.0 + jax.scipy.special.erf((z - vth0) / (sigma_z * jnp.sqrt(2.0)))
    )


def wta_expected_probs(
    z: jax.Array, vth0: float, sigma_z: float | None = None, beta: float = 1.0
) -> jax.Array:
    """First-order analytic P_WTA (Eq. 14 LHS): fire probs normalized.

    Exact when at most one neuron fires per trial (the high-threshold
    regime); tests compare this and true softmax against simulated counts.
    """
    p = wta_fire_probability(z, vth0, sigma_z, beta)
    return p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)


def wta_topk(
    key: jax.Array,
    z: jax.Array,
    k: int,
    n_trials: int,
    vth0: float,
    sigma_z: float | None = None,
    beta: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    """k-winner WTA: top-k of cumulative counts (MoE-router generalization).

    Ties (zero counts) are broken by z so that the result is always a valid
    set of k experts.  Returns (values=vote shares, indices)."""
    res = wta_trials(key, z, n_trials, vth0, sigma_z, beta)
    score = res.counts + 1e-6 * jax.nn.softmax(z, axis=-1)
    vals, idx = jax.lax.top_k(score, k)
    share = vals / jnp.maximum(res.counts.sum(axis=-1, keepdims=True), 1.0)
    return share, idx
