"""Composable analog-execution modules — the paper's technique as a first-
class feature any model in the framework can opt into.

Three execution modes per wrapped matmul:

* ``digital``            — plain jnp matmul (reference / non-analog deploy).
* ``analog_linear``      — crossbar MAC with conductance quantization and
                           thermal noise, ideal linear readout (the
                           "1-bit-ADC-free but still converted" baseline used
                           for noise-aware training of non-sigmoidal archs).
* ``analog_stochastic``  — the full RACA path: crossbar MAC → thermal noise →
                           comparator → binary stochastic activation (no ADC,
                           no DAC downstream).  Output is {0,1}.

`use_pallas="auto"` routes the hot path through the fused Pallas TPU kernel
(kernels/crossbar_mac) when running on TPU; on CPU (this container, and the
512-device dry-run) the numerically-identical jnp reference executes so that
GSPMD lowering is exercised end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import crossbar, neurons, wta
from .physics import DeviceParams


@dataclasses.dataclass(frozen=True)
class AnalogConfig:
    mode: str = "digital"  # digital | analog_linear | analog_stochastic
    device: DeviceParams = dataclasses.field(default_factory=DeviceParams)
    beta: float = 1.0          # logistic slope the SNR is calibrated to
    hard: bool = True          # hard Bernoulli sample vs expectation (eval)
    quantize: bool = True      # conductance-level quantization of weights
    calibrated: bool = True    # calibrated P=sigmoid(beta z) vs physical ΣG
    use_pallas: str = "auto"   # auto | on | off
    rows_per_tile: int = 256   # physical array height (cost model, kernels)
    wta_trials: int = 32       # decision trials for WTA readout heads
    wta_vth0: Optional[float] = None  # None => calibrated θ = σ² (temp 1)
    # analog_linear mode reads at NORMAL voltage (high SNR — the low-SNR
    # regime is only for the stochastic-neuron trick): input-referred noise
    # std relative to the layer's dynamic range.
    linear_sigma: float = 0.01

    def with_mode(self, mode: str) -> "AnalogConfig":
        return dataclasses.replace(self, mode=mode)

    @property
    def vth0(self) -> float:
        if self.wta_vth0 is not None:
            return self.wta_vth0
        return wta.calibrated_threshold(self.beta)


DIGITAL = AnalogConfig(mode="digital")


def _pallas_enabled(cfg: AnalogConfig) -> bool:
    if cfg.use_pallas == "on":
        return True
    if cfg.use_pallas == "off":
        return False
    return jax.default_backend() == "tpu"


def dynamic_range(w: jax.Array) -> jax.Array:
    """Per-layer conductance-range scale s = max|W| (the paper's G0/V_r
    calibration knob, Fig. 4(c)-(d)): weights map to devices as W/s so the
    full conductance range is used regardless of the layer's weight scale;
    the comparator slope (via V_r) absorbs s back."""
    return jax.lax.stop_gradient(
        jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
    )


def quantize_normalized(w: jax.Array, cfg: AnalogConfig) -> jax.Array:
    """s · quantize(w / s): dynamic-range conductance quantization, with a
    straight-through gradient (jnp.round is otherwise zero-grad — QAT would
    silently stop training the quantized weights)."""
    if not cfg.quantize:
        return w
    s = dynamic_range(w)
    wq = s * crossbar.quantize_weights(w / s, cfg.device)
    return w + jax.lax.stop_gradient(wq - w)


def analog_matmul(
    cfg: AnalogConfig,
    key: Optional[jax.Array],
    x: jax.Array,
    w: jax.Array,
) -> jax.Array:
    """Matmul under the configured execution mode.  x: (..., in), w: (in, out).

    ``analog_stochastic`` returns binary activations sampled through the STE
    (trainable); the other modes return continuous outputs in x.dtype.
    """
    if cfg.mode == "digital" or key is None:
        return x @ w.astype(x.dtype)

    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)

    if cfg.mode == "analog_linear":
        if _pallas_enabled(cfg):
            from repro.kernels import ops as kops  # lazy: avoid cycles

            y = kops.crossbar_mac(xf, wf, key, cfg, binarize=False)
        else:
            s = dynamic_range(wf)
            wq = quantize_normalized(wf, cfg)
            noise = jax.random.normal(key, xf.shape[:-1] + (w.shape[-1],))
            y = xf @ wq + s * cfg.linear_sigma * noise
        return y.astype(orig_dtype)

    if cfg.mode == "analog_stochastic":
        if _pallas_enabled(cfg):
            from repro.kernels import ops as kops

            y = kops.crossbar_mac(xf, wf, key, cfg, binarize=True)
        elif cfg.calibrated:
            wq = quantize_normalized(wf, cfg)
            y = neurons.sigmoid_neuron_calibrated(
                key, xf @ wq, beta=cfg.beta, hard=cfg.hard
            )
        else:
            y = neurons.sigmoid_neuron_physical(
                key, xf, wf, cfg.device, hard=cfg.hard
            )
        return y.astype(orig_dtype)

    raise ValueError(f"unknown analog mode: {cfg.mode!r}")


def analog_dense(
    cfg: AnalogConfig,
    key: Optional[jax.Array],
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
) -> jax.Array:
    """Dense layer; bias is realized digitally (a bias row in hardware)."""
    if cfg.mode == "analog_stochastic" and b is not None and key is not None:
        # Fold the bias into the pre-activation before the comparator: in
        # hardware this is an always-on bias wordline, so it must be applied
        # before binarization, not after.
        orig_dtype = x.dtype
        xf, wf = x.astype(jnp.float32), w.astype(jnp.float32)
        wq = quantize_normalized(wf, cfg)
        z = xf @ wq + b.astype(jnp.float32)
        y = neurons.sigmoid_neuron_calibrated(key, z, beta=cfg.beta, hard=cfg.hard)
        return y.astype(orig_dtype)
    y = analog_matmul(cfg, key, x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def wta_head(
    cfg: AnalogConfig,
    key: Optional[jax.Array],
    z: jax.Array,
) -> wta.WTAResult:
    """WTA stochastic SoftMax readout over logits ``z`` (classifier head)."""
    assert key is not None, "WTA head requires a PRNG key"
    return wta.wta_trials(
        key,
        z.astype(jnp.float32),
        n_trials=cfg.wta_trials,
        vth0=cfg.vth0,
        beta=cfg.beta,
    )


def wta_router_topk(
    cfg: AnalogConfig,
    key: Optional[jax.Array],
    logits: jax.Array,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """MoE router as a k-winner WTA circuit; digital top-k when key is None."""
    if key is None or cfg.mode != "analog_stochastic":
        vals, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
        return vals, idx
    return wta.wta_topk(
        key,
        logits.astype(jnp.float32),
        k,
        n_trials=cfg.wta_trials,
        vth0=cfg.vth0,
        beta=cfg.beta,
    )
