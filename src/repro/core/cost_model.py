"""NeuroSim-style component-level energy/area model (paper §IV-C, Table I).

Reproduces Table I for the FCNN [784, 500, 300, 10] on MNIST and generalizes
to arbitrary layer stacks, comparing two readout schemes:

* ``ADC1B`` — conventional CiM: DACs at every layer input (bit-serial, 8-bit),
  per-tile partial sums read by 1-bit ADCs (sense amplifiers, column-muxed),
  explicit digital Sigmoid/SoftMax activation logic.
* ``RACA``  — the paper: DAC only at the input stage, analog current summing
  across tiles, one comparator(+TIA) per logical output column, no activation
  logic (the comparator IS the activation), T stochastic trials per decision.

Component constants are *calibrated* so the FCNN lands exactly on Table I
(8.7e5 pJ / 8.51 mm^2 / 61.3 TOPS/W vs 3.63e5 pJ / 5.24 mm^2 / 148.58
TOPS/W), under the published constraint that DACs+ADCs are ~72% of energy
and ~81% of area in conventional designs [9].  Derivation in comments below;
the model then *predicts* costs for other network shapes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# Structural accounting.
# ---------------------------------------------------------------------------

ARRAY_ROWS = 128          # physical crossbar tile height
ADC_SHARE = 8             # columns muxed per 1-bit ADC (conventional scheme)
INPUT_BITS = 8            # bit-serial input precision (conventional + input DAC)


def _layers_macs(layers: Sequence[int]) -> int:
    return sum(a * b for a, b in zip(layers[:-1], layers[1:]))


def _conv_counts(layers: Sequence[int]) -> dict:
    """Counts per single inference pass (one trial)."""
    tiles_per_layer = [math.ceil(a / ARRAY_ROWS) for a in layers[:-1]]
    phys_cols = sum(t * b for t, b in zip(tiles_per_layer, layers[1:]))
    return dict(
        macs=_layers_macs(layers),
        # conventional: every physical column converted each input bit-cycle
        adc_conversions=phys_cols * INPUT_BITS,
        # conventional: DACs at every layer input, bit-serial
        dac_inputs_all=sum(layers[:-1]),
        # RACA: analog tile-summing -> one comparator per logical column
        comparator_cols=sum(layers[1:]),
        dac_inputs_first=layers[0],
        phys_cols=phys_cols,
    )


# ---------------------------------------------------------------------------
# Calibrated component constants (32 nm, from Table I + the 72%/81% split).
#
# Energy [pJ]:  E1 = E_common + E_act + E_dac_all + E_adc_total = 8.70e5
#   with (DAC+ADC) = 72%  =>  E_dac_all + E_adc_total = 6.264e5
#   split: ADC 4.704e5 over 37840 conversions  => e_adc  = 12.432 pJ
#          DAC 1.560e5 over 12672 conversions  => e_dac  = 12.311 pJ (8-bit)
#   E_common (arrays/buffers/routing) = 1.860e5, E_act (digital σ/softmax
#   units) = 0.576e5  =>  E1 = 8.700e5 ✓
#   RACA, T=10 trials: E2 = E_common + T·(784·e_dac) + T·(810·e_cmp) = 3.63e5
#          => e_cmp = 9.944 pJ  (0.80× of a 1-bit ADC conversion: plausible
#             for a clocked comparator + TIA at 32 nm) ✓
#
# Area [mm^2]:  A1 = A_common + A_act + A_dac + A_adc = 8.51
#   with (DAC+ADC) = 81%  =>  6.893;  split ADC 5.500 over 4730/8 shared
#   units => a_adc = 9.306e-3;  DAC 1.393 over 1584 => a_dac = 8.794e-4
#   A_common = 1.317, A_act = 0.300  =>  A1 = 8.510 ✓
#   RACA: A2 = A_common + 784·a_dac + 810·a_cmp = 5.24
#          => a_cmp = 3.992e-3 (no column muxing — cheap enough to be fully
#             parallel, which is what enables the single-cycle WTA race) ✓
# ---------------------------------------------------------------------------

E_MAC = 0.0           # array read energy folded into E_COMMON_REF (below)
E_ADC = 12.432        # pJ per 1-bit ADC conversion
E_DAC = 12.311        # pJ per 8-bit DAC conversion
E_CMP = 9.944         # pJ per comparator decision (incl. TIA)
E_COMMON_REF = 1.860e5  # pJ, arrays+buffers+routing for the reference FCNN
E_ACT_REF = 0.576e5     # pJ, digital activation logic for the reference FCNN

A_ADC = 9.306e-3      # mm^2 per shared 1-bit ADC unit
A_DAC = 8.794e-4      # mm^2 per DAC
A_CMP = 3.992e-3      # mm^2 per comparator+TIA
A_COMMON_REF = 1.317  # mm^2 arrays+digital for the reference FCNN
A_ACT_REF = 0.300     # mm^2 digital activation units

RACA_TRIALS = 10      # decision trials counted in Table I's RACA column

# NeuroSim's OP accounting (ops per inference) back-solved from Table I's
# TOPS/W columns; the two schemes differ by ~1% from published rounding.
OPS_REF_ADC = 61.30e12 * 8.70e5 * 1e-12   # = 5.333e7
OPS_REF_RACA = 148.58e12 * 3.63e5 * 1e-12  # = 5.393e7

_REF_LAYERS = (784, 500, 300, 10)
_REF_COUNTS = _conv_counts(_REF_LAYERS)


@dataclasses.dataclass(frozen=True)
class HardwareCost:
    energy_pj: float
    area_mm2: float
    tops_per_w: float


def _scale(counts: dict) -> float:
    """Scale common (array/buffer) terms by MAC count relative to reference."""
    return counts["macs"] / _REF_COUNTS["macs"]


def cost_adc1b(layers: Sequence[int] = _REF_LAYERS) -> HardwareCost:
    c = _conv_counts(layers)
    s = _scale(c)
    energy = (
        E_COMMON_REF * s
        + E_ACT_REF * s
        + c["dac_inputs_all"] * INPUT_BITS * E_DAC
        + c["adc_conversions"] * E_ADC
    )
    area = (
        A_COMMON_REF * s
        + A_ACT_REF * s
        + c["dac_inputs_all"] * A_DAC
        + math.ceil(c["phys_cols"] / ADC_SHARE) * A_ADC
    )
    ops = OPS_REF_ADC * s
    return HardwareCost(energy, area, ops / (energy * 1e-12) / 1e12)


def cost_raca(
    layers: Sequence[int] = _REF_LAYERS, trials: int = RACA_TRIALS
) -> HardwareCost:
    c = _conv_counts(layers)
    s = _scale(c)
    energy = (
        E_COMMON_REF * s
        + trials * c["dac_inputs_first"] * E_DAC
        + trials * c["comparator_cols"] * E_CMP
    )
    area = (
        A_COMMON_REF * s
        + c["dac_inputs_first"] * A_DAC
        + c["comparator_cols"] * A_CMP
    )
    ops = OPS_REF_RACA * s
    return HardwareCost(energy, area, ops / (energy * 1e-12) / 1e12)


def table1(layers: Sequence[int] = _REF_LAYERS) -> dict:
    """Reproduce Table I: both schemes + percentage changes."""
    a = cost_adc1b(layers)
    r = cost_raca(layers)
    return {
        "adc1b": a,
        "raca": r,
        "energy_change_pct": (r.energy_pj - a.energy_pj) / a.energy_pj * 100,
        "area_change_pct": (r.area_mm2 - a.area_mm2) / a.area_mm2 * 100,
        "efficiency_change_pct": (r.tops_per_w - a.tops_per_w)
        / a.tops_per_w
        * 100,
    }


PAPER_TABLE1 = {
    "adc1b": HardwareCost(8.70e5, 8.51, 61.3),
    "raca": HardwareCost(3.63e5, 5.24, 148.58),
    "energy_change_pct": -58.29,
    "area_change_pct": -38.43,
    "efficiency_change_pct": +142.37,
}
