"""NeuroSim-style component-level energy/area model (paper §IV-C, Table I).

Reproduces Table I for the FCNN [784, 500, 300, 10] on MNIST and generalizes
to arbitrary layer stacks, comparing two readout schemes:

* ``ADC1B`` — conventional CiM: DACs at every layer input (bit-serial, 8-bit),
  per-tile partial sums read by 1-bit ADCs (sense amplifiers, column-muxed),
  explicit digital Sigmoid/SoftMax activation logic.
* ``RACA``  — the paper: DAC only at the input stage, analog current summing
  across tiles, one comparator(+TIA) per logical output column, no activation
  logic (the comparator IS the activation), T stochastic trials per decision.

Component constants are *calibrated* so the FCNN lands exactly on Table I
(8.7e5 pJ / 8.51 mm^2 / 61.3 TOPS/W vs 3.63e5 pJ / 5.24 mm^2 / 148.58
TOPS/W), under the published constraint that DACs+ADCs are ~72% of energy
and ~81% of area in conventional designs [9].  Derivation in comments below;
the model then *predicts* costs for other network shapes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# Structural accounting.
# ---------------------------------------------------------------------------

ARRAY_ROWS = 128          # physical crossbar tile height
ADC_SHARE = 8             # columns muxed per 1-bit ADC (conventional scheme)
INPUT_BITS = 8            # bit-serial input precision (conventional + input DAC)


def _layers_macs(layers: Sequence[int]) -> int:
    return sum(a * b for a, b in zip(layers[:-1], layers[1:]))


def _conv_counts(layers: Sequence[int]) -> dict:
    """Counts per single inference pass (one trial)."""
    tiles_per_layer = [math.ceil(a / ARRAY_ROWS) for a in layers[:-1]]
    phys_cols = sum(t * b for t, b in zip(tiles_per_layer, layers[1:]))
    return dict(
        macs=_layers_macs(layers),
        # conventional: every physical column converted each input bit-cycle
        adc_conversions=phys_cols * INPUT_BITS,
        # conventional: DACs at every layer input, bit-serial
        dac_inputs_all=sum(layers[:-1]),
        # RACA: analog tile-summing -> one comparator per logical column
        comparator_cols=sum(layers[1:]),
        dac_inputs_first=layers[0],
        phys_cols=phys_cols,
    )


# ---------------------------------------------------------------------------
# Calibrated component constants (32 nm, from Table I + the 72%/81% split).
#
# Energy [pJ]:  E1 = E_common + E_act + E_dac_all + E_adc_total = 8.70e5
#   with (DAC+ADC) = 72%  =>  E_dac_all + E_adc_total = 6.264e5
#   split: ADC 4.704e5 over 37840 conversions  => e_adc  = 12.432 pJ
#          DAC 1.560e5 over 12672 conversions  => e_dac  = 12.311 pJ (8-bit)
#   E_common (arrays/buffers/routing) = 1.860e5, E_act (digital σ/softmax
#   units) = 0.576e5  =>  E1 = 8.700e5 ✓
#   RACA, T=10 trials: E2 = E_common + T·(784·e_dac) + T·(810·e_cmp) = 3.63e5
#          => e_cmp = 9.944 pJ  (0.80× of a 1-bit ADC conversion: plausible
#             for a clocked comparator + TIA at 32 nm) ✓
#
# Area [mm^2]:  A1 = A_common + A_act + A_dac + A_adc = 8.51
#   with (DAC+ADC) = 81%  =>  6.893;  split ADC 5.500 over the
#   ceil(4730/8) = 592 shared units the layout actually instantiates (a
#   fractional ADC cannot be placed; cost_adc1b ceils the same way)
#   => a_adc = 5.500/592 = 9.2905e-3;  DAC 1.393 over 1584 => a_dac =
#   8.794e-4;  A_common = 1.317, A_act = 0.300  =>  A1 = 8.510 ✓
#   RACA: A2 = A_common + 784·a_dac + 810·a_cmp = 5.24
#          => a_cmp = 3.992e-3 (no column muxing — cheap enough to be fully
#             parallel, which is what enables the single-cycle WTA race) ✓
# ---------------------------------------------------------------------------

E_MAC = 0.0           # array read energy folded into E_COMMON_REF (below)
E_ADC = 12.432        # pJ per 1-bit ADC conversion
E_DAC = 12.311        # pJ per 8-bit DAC conversion
E_CMP = 9.944         # pJ per comparator decision (incl. TIA)
E_COMMON_REF = 1.860e5  # pJ, arrays+buffers+routing for the reference FCNN
E_ACT_REF = 0.576e5     # pJ, digital activation logic for the reference FCNN

# mm^2 per shared 1-bit ADC unit — calibrated over the ceil'd unit count
# (592 for the reference FCNN) so the calibration and cost_adc1b use the
# SAME discretization and table1() lands exactly on PAPER_TABLE1
A_ADC = 5.500 / 592
A_DAC = 8.794e-4      # mm^2 per DAC
A_CMP = 3.992e-3      # mm^2 per comparator+TIA
A_COMMON_REF = 1.317  # mm^2 arrays+digital for the reference FCNN
A_ACT_REF = 0.300     # mm^2 digital activation units

RACA_TRIALS = 10      # decision trials counted in Table I's RACA column

# NeuroSim's OP accounting (ops per inference) back-solved from Table I's
# TOPS/W columns; the two schemes differ by ~1% from published rounding.
OPS_REF_ADC = 61.30e12 * 8.70e5 * 1e-12   # = 5.333e7
OPS_REF_RACA = 148.58e12 * 3.63e5 * 1e-12  # = 5.393e7

_REF_LAYERS = (784, 500, 300, 10)
_REF_COUNTS = _conv_counts(_REF_LAYERS)


@dataclasses.dataclass(frozen=True)
class HardwareCost:
    energy_pj: float
    area_mm2: float
    tops_per_w: float


def _scale(counts: dict) -> float:
    """Scale common (array/buffer) terms by MAC count relative to reference."""
    return counts["macs"] / _REF_COUNTS["macs"]


def cost_adc1b(layers: Sequence[int] = _REF_LAYERS) -> HardwareCost:
    c = _conv_counts(layers)
    s = _scale(c)
    energy = (
        E_COMMON_REF * s
        + E_ACT_REF * s
        + c["dac_inputs_all"] * INPUT_BITS * E_DAC
        + c["adc_conversions"] * E_ADC
    )
    area = (
        A_COMMON_REF * s
        + A_ACT_REF * s
        + c["dac_inputs_all"] * A_DAC
        + math.ceil(c["phys_cols"] / ADC_SHARE) * A_ADC
    )
    ops = OPS_REF_ADC * s
    return HardwareCost(energy, area, ops / (energy * 1e-12) / 1e12)


def cost_raca(
    layers: Sequence[int] = _REF_LAYERS, trials: int = RACA_TRIALS
) -> HardwareCost:
    c = _conv_counts(layers)
    s = _scale(c)
    energy = (
        E_COMMON_REF * s
        + trials * c["dac_inputs_first"] * E_DAC
        + trials * c["comparator_cols"] * E_CMP
    )
    area = (
        A_COMMON_REF * s
        + c["dac_inputs_first"] * A_DAC
        + c["comparator_cols"] * A_CMP
    )
    ops = OPS_REF_RACA * s
    return HardwareCost(energy, area, ops / (energy * 1e-12) / 1e12)


def table1(layers: Sequence[int] = _REF_LAYERS) -> dict:
    """Reproduce Table I: both schemes + percentage changes."""
    a = cost_adc1b(layers)
    r = cost_raca(layers)
    return {
        "adc1b": a,
        "raca": r,
        "energy_change_pct": (r.energy_pj - a.energy_pj) / a.energy_pj * 100,
        "area_change_pct": (r.area_mm2 - a.area_mm2) / a.area_mm2 * 100,
        "efficiency_change_pct": (r.tops_per_w - a.tops_per_w)
        / a.tops_per_w
        * 100,
    }


PAPER_TABLE1 = {
    "adc1b": HardwareCost(8.70e5, 8.51, 61.3),
    "raca": HardwareCost(3.63e5, 5.24, 148.58),
    "energy_change_pct": -58.29,
    "area_change_pct": -38.43,
    "efficiency_change_pct": +142.37,
}


# ---------------------------------------------------------------------------
# Served-traffic accounting: per-token analog event counts for the LM zoo.
#
# The FCNN model above prices a whole inference pass; the serving engine
# needs the same Table I constants applied to the *event counts one decoded
# (or prefilled, or drafted) token drives through the crossbar fabric*.
# Counts are a pure function of the ModelConfig's weight-matmul shapes —
# NOT of batch composition, arrival order, or sharding — which is what
# makes `total counts == tokens_computed x per-token counts` an exact,
# test-pinnable invariant (tests/test_energy_accounting.py).
#
# Conventions (documented in docs/serving.md §"Energy accounting"):
#   * Only WEIGHT matmuls count as crossbar work: ReRAM arrays hold
#     weights, so attention's position-dependent score/value products
#     (activation x activation) run in the digital/peripheral domain and
#     are covered by the MAC-scaled common term, like buffers and routing.
#   * tile_reads: physical column reads — ceil(K / ARRAY_ROWS) tiles per
#     logical column, N columns per (K, N) matmul.
#   * comparator_decisions: RACA's readout, T stochastic trials per
#     logical output column; WTA sampling adds wta_trials x vocab per
#     sampled token.
#   * dac_conversions: RACA drives DACs only at the input stage (T trials
#     re-drive d_model lines per token); the ADC1B mirror instead pays
#     bit-serial DACs at EVERY layer input plus 1-bit ADC reads of every
#     physical column x INPUT_BITS — exactly the cost_adc1b / cost_raca
#     split above, restated per token.
#   * stoch_round_events: int8 KV-cache writes; each element rounded is
#     one comparator-style decision (the paper's conductance-programming
#     primitive), priced at E_CMP under BOTH schemes — quantized cache
#     writes are not part of the readout-scheme comparison.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AnalogOpCounts:
    """Exact analog event counts (integers; addition and scaling close)."""

    macs: int = 0
    tile_reads: int = 0
    comparator_decisions: int = 0
    dac_conversions: int = 0
    adc1b_dac_conversions: int = 0
    adc1b_adc_conversions: int = 0
    stoch_round_events: int = 0
    wta_samples: int = 0

    def __add__(self, other: "AnalogOpCounts") -> "AnalogOpCounts":
        return AnalogOpCounts(
            **{
                f.name: getattr(self, f.name) + getattr(other, f.name)
                for f in dataclasses.fields(self)
            }
        )

    def scaled(self, n: int) -> "AnalogOpCounts":
        """Counts for ``n`` identical events (n == 0 is the zero element)."""
        return AnalogOpCounts(
            **{
                f.name: getattr(self, f.name) * n
                for f in dataclasses.fields(self)
            }
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AnalogOpCounts":
        """Rebuild from a JSON round-trip (validate_report reconciliation)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in names})


def _mlp_matmuls(cfg) -> list:
    d, f = cfg.d_model, cfg.d_ff
    mm = [(d, f), (f, d)]
    if cfg.mlp in ("swiglu", "geglu"):
        mm.append((d, f))  # w_gate
    return mm


def _ffn_matmuls(cfg) -> list:
    if cfg.family == "moe_lm":
        # router + the top-k experts a decoded token actually dispatches to
        return [(cfg.d_model, cfg.n_experts)] + (
            _mlp_matmuls(cfg) * max(cfg.moe_topk, 1)
        )
    return _mlp_matmuls(cfg)


def per_token_weight_matmuls(cfg) -> tuple:
    """(K, N) of every weight matmul one token's forward pass drives.

    Enumerates the parameter tensors each layer kind applies per position
    (models/transformer.py block structure: attention kinds carry an FFN,
    "rec" carries RG-LRU + FFN, "ssm" is the Mamba mixer alone) plus the
    LM head — the logits matmul runs for every computed token, tied
    embeddings included."""
    d, hd = cfg.d_model, cfg.head_dim
    unit: list = []
    for kind in cfg.layer_pattern:
        if kind == "rec":
            w = cfg.lru_width or d
            unit += [(d, w), (d, w), (w, w), (w, w), (w, d)]
            unit += _ffn_matmuls(cfg)
        elif kind == "ssm":
            unit += [
                (d, 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_nheads),
                (cfg.d_inner, d),
            ]
        elif kind in ("global", "local", "attn"):
            unit += [
                (d, cfg.n_heads * hd),
                (d, cfg.n_kv_heads * hd),
                (d, cfg.n_kv_heads * hd),
                (cfg.n_heads * hd, d),
            ]
            unit += _ffn_matmuls(cfg)
        else:
            raise ValueError(
                f"unknown layer kind {kind!r} in layer_pattern — the "
                "analog accounting cannot price a layer it cannot "
                "enumerate"
            )
    return tuple(unit * cfg.n_units) + ((d, cfg.vocab),)


def per_token_analog_counts(cfg) -> AnalogOpCounts:
    """Analog events ONE computed token drives (prefill == decode == draft:
    every computed position runs the same weight matmuls)."""
    macs = tile_reads = cmp_dec = a_dac = a_adc = 0
    for k, n in per_token_weight_matmuls(cfg):
        tiles = math.ceil(k / ARRAY_ROWS)
        macs += k * n
        tile_reads += tiles * n
        cmp_dec += RACA_TRIALS * n
        a_dac += k * INPUT_BITS
        a_adc += tiles * n * INPUT_BITS
    return AnalogOpCounts(
        macs=macs,
        tile_reads=tile_reads,
        comparator_decisions=cmp_dec,
        # RACA: input-stage DACs only, re-driven once per decision trial
        dac_conversions=RACA_TRIALS * cfg.d_model,
        adc1b_dac_conversions=a_dac,
        adc1b_adc_conversions=a_adc,
    )


def per_sample_analog_counts(cfg) -> AnalogOpCounts:
    """Events one TOKEN-SAMPLING decision adds on top of the forward pass.

    The WTA stochastic-SoftMax head races wta_trials comparator banks over
    the vocab columns; greedy argmax is digital and adds nothing."""
    if not getattr(cfg, "wta_head", False):
        return AnalogOpCounts()
    return AnalogOpCounts(
        comparator_decisions=cfg.analog.wta_trials * cfg.vocab,
        wta_samples=1,
    )


def per_redundant_read_counts(cfg) -> AnalogOpCounts:
    """Events ONE redundant comparator re-read adds (fault mitigation).

    With ``n_redundant_reads = R`` the WTA head re-races its full trial
    bank R-1 extra times per sampled token and majority-votes; each extra
    read costs exactly one more per-sample comparator sweep (but not a
    wta_samples event — the published sample count is unchanged).  Greedy
    heads re-read nothing (digital argmax is deterministic)."""
    if not getattr(cfg, "wta_head", False):
        return AnalogOpCounts()
    return AnalogOpCounts(
        comparator_decisions=cfg.analog.wta_trials * cfg.vocab,
    )


def per_kv_token_round_events(cfg) -> AnalogOpCounts:
    """Stochastic-rounding events one KV-WRITTEN token adds (int8 pools).

    K and V rows of every attention layer are rounded element-wise onto
    the int8 grid; read-only passes (speculative verify) write nothing."""
    if getattr(cfg, "kv_cache_dtype", "same") != "int8":
        return AnalogOpCounts()
    n_attn = cfg.n_units * sum(
        1 for k in cfg.layer_pattern if k not in ("rec", "ssm")
    )
    return AnalogOpCounts(
        stoch_round_events=2 * n_attn * cfg.n_kv_heads * cfg.head_dim
    )


def price_counts(counts: AnalogOpCounts) -> dict:
    """Price an event tally under both readout schemes, in pJ.

    The MAC-scaled common term (arrays, buffers, routing — covering the
    digital attention/softmax peripherals too) is shared; the schemes then
    differ exactly as in cost_adc1b / cost_raca: ADC1B pays activation
    logic + every-layer bit-serial DACs + per-physical-column 1-bit ADC
    reads, RACA pays input-stage DACs + one comparator decision per trial
    per logical column.  Stochastic KV rounding prices identically in
    both (it is cache-write hardware, not readout)."""
    s = counts.macs / _REF_COUNTS["macs"]
    common = E_COMMON_REF * s
    round_pj = counts.stoch_round_events * E_CMP
    raca = (
        common
        + counts.dac_conversions * E_DAC
        + counts.comparator_decisions * E_CMP
        + round_pj
    )
    adc1b = (
        common
        + E_ACT_REF * s
        + counts.adc1b_dac_conversions * E_DAC
        + counts.adc1b_adc_conversions * E_ADC
        + round_pj
    )
    return {"raca_energy_pj": raca, "adc1b_energy_pj": adc1b}


def effective_tops_per_w(counts: AnalogOpCounts, energy_pj: float) -> float:
    """Executed TOPS/W: 2 ops per MAC over the priced energy (1 op/pJ ==
    1 TOPS/W), the workload-measured counterpart of Table I's column."""
    return 2.0 * counts.macs / max(energy_pj, 1e-30)
