"""ReRAM crossbar weight mapping and analog MAC simulation (paper §II-B).

Implements Eq. 4-7 plus the non-idealities that matter for deployment:
conductance quantization to ``n_levels`` and Gaussian programming noise.

The simulated crossbar computes, per output column j (Eq. 9-12):

    I_j     = Σ_i V_i · G_ij + noise_j,   G_ij = W_ij·G0 + G_ref
    I_ref   = Σ_i V_i · G_ref + noise_ref
    E[I_j - I_ref] = V_r · G0 · Σ_i W_ij x_i = V_r · G0 · z_j

Tall weight matrices are tiled into physical arrays of ``rows_per_tile``
wordlines whose columns share a summing TIA (current summing across arrays),
so the differential mean is exact and the noise variance accumulates over
*all* rows — matching Eq. 13's denominator.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .physics import BOLTZMANN_K, DeviceParams, column_noise_sigma


class CrossbarMapping(NamedTuple):
    """Conductance-domain view of a weight matrix."""

    g: jax.Array          # (in, out) device conductances [S]
    g_ref: jax.Array      # scalar reference conductance [S]
    w_eff: jax.Array      # effective (quantized) weights seen by the algorithm


def quantize_weights(
    w: jax.Array,
    dp: DeviceParams,
    key: Optional[jax.Array] = None,
    stochastic: bool = False,
) -> jax.Array:
    """Quantize weights to the grid realizable by ``n_levels`` conductances.

    Round-to-nearest by default; stochastic rounding (unbiased) when a key is
    given — the same primitive the `kernels/stoch_round` Pallas kernel
    implements for the hot path.
    """
    w = jnp.clip(w, dp.w_min, dp.w_max)
    if dp.n_levels <= 1:
        return w
    step = (dp.w_max - dp.w_min) / (dp.n_levels - 1)
    t = (w - dp.w_min) / step
    if stochastic and key is not None:
        floor = jnp.floor(t)
        frac = t - floor
        up = jax.random.uniform(key, w.shape) < frac
        t = floor + up.astype(w.dtype)
    else:
        t = jnp.round(t)
    return t * step + dp.w_min


def map_weights(
    w: jax.Array,
    dp: DeviceParams,
    key: Optional[jax.Array] = None,
    quantize: bool = True,
) -> CrossbarMapping:
    """Map algorithmic weights to conductances (Eq. 4-7)."""
    kq = kp = None
    if key is not None:
        kq, kp = jax.random.split(key)
    w_eff = quantize_weights(w, dp, kq, stochastic=key is not None) if quantize else w
    g = w_eff * dp.g0 + dp.g_ref  # Eq. 7
    if dp.sigma_program > 0.0 and kp is not None:
        g = g + jax.random.normal(kp, g.shape) * (
            dp.sigma_program * (dp.g_max - dp.g_min)
        )
        g = jnp.clip(g, dp.g_min, dp.g_max)
    w_eff = (g - dp.g_ref) / dp.g0  # weights actually realized
    return CrossbarMapping(g=g, g_ref=jnp.asarray(dp.g_ref), w_eff=w_eff)


def column_sum_g(mapping: CrossbarMapping) -> jax.Array:
    """Σ_i (G_ij + G_ref) per output column — Eq. 13's noise denominator."""
    n_rows = mapping.g.shape[0]
    return mapping.g.sum(axis=0) + n_rows * mapping.g_ref


def analog_mac(
    key: jax.Array,
    x: jax.Array,
    mapping: CrossbarMapping,
    dp: DeviceParams,
) -> tuple[jax.Array, jax.Array]:
    """Differential analog MAC: returns (delta_i, sigma_col).

    ``delta_i`` is the noisy differential current I_j - I_ref (Eq. 9-12),
    with mean V_r·G0·(x @ W_eff); ``sigma_col`` the per-column noise std.
    ``x`` has shape (..., in); output (..., out).
    """
    v = x.astype(jnp.float32) * dp.v_read  # Eq. 6
    mean = v @ (mapping.g - mapping.g_ref)  # == Vr·G0·(x@W_eff), Eq. 12
    sum_g = column_sum_g(mapping)  # (out,)
    sigma = column_noise_sigma(sum_g, dp)
    noise = jax.random.normal(key, mean.shape, dtype=jnp.float32) * sigma
    return mean + noise, sigma


def analog_matmul_zspace(
    key: jax.Array,
    x: jax.Array,
    w: jax.Array,
    dp: DeviceParams,
    quantize: bool = True,
    map_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Analog matmul with *input-referred* noise, returned in z-units.

    This is the "ideal-ADC readout" view used to wrap arbitrary matmuls in
    large models (noise-aware training): y = x@W_eff + n/(V_r·G0).  The RACA
    binary readout instead feeds ``analog_mac`` output into a comparator
    (see neurons.py).
    """
    mapping = map_weights(w, dp, key=map_key, quantize=quantize)
    delta_i, _ = analog_mac(key, x, mapping, dp)
    return delta_i / (dp.v_read * dp.g0)


def zspace_noise_sigma(w: jax.Array, dp: DeviceParams) -> jax.Array:
    """Per-column noise std in z-units: sigma_I / (V_r·G0)."""
    n_rows = w.shape[0]
    sum_g = (w * dp.g0 + dp.g_ref).sum(axis=0) + n_rows * dp.g_ref
    return column_noise_sigma(sum_g, dp) / (dp.v_read * dp.g0)


def tile_count(n_rows: int, rows_per_tile: int) -> int:
    """Physical arrays needed for a (n_rows, ·) matrix (cost model input)."""
    return -(-n_rows // rows_per_tile)
