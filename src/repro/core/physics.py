"""Device physics for the RACA accelerator (paper §II, Eq. 1-3).

Johnson-Nyquist thermal noise of ReRAM devices is the entropy source that the
whole paper rests on: a bare comparator on a noisy column current becomes a
stochastic binary neuron.  Everything here is in SI units.

    i_RMS = sqrt(4 k T G Δf)                      (Eq. 1)
    SNR   = 10 log10(P_signal / P_noise)          (Eq. 2)
    P_noise = i_RMS^2 · R                         (Eq. 3)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# Boltzmann constant [J/K].
BOLTZMANN_K = 1.380649e-23

# Probit->logit matching constant: logistic(z) ~= Phi(z / PROBIT_SCALE).
# (Classical 1.702 approximation; max abs error < 0.0095 over all z.)
PROBIT_SCALE = 1.702


@dataclasses.dataclass(frozen=True)
class DeviceParams:
    """Physical parameters of the ReRAM array + readout (paper §II, §IV).

    Defaults model the paper's Ag:Si devices in the *low-SNR read regime*:
    the read voltage is deliberately much smaller than a normal ReRAM read so
    that the signal lands inside the thermal-noise band (paper §IV-C).
    """

    g_min: float = 1.0e-6        # [S] low conductance state (1 MΩ)
    g_max: float = 1.0e-4        # [S] high conductance state (10 kΩ)
    n_levels: int = 32           # programmable conductance levels
    sigma_program: float = 0.0   # programming noise, fraction of (g_max-g_min)
    temperature: float = 300.0   # [K]
    delta_f: float = 1.0e9       # [Hz] readout bandwidth
    v_read: float = 1.0e-3       # [V] V_r, read voltage amplitude (calibrated)
    w_max: float = 1.0           # algorithmic weight clip range
    w_min: float = -1.0

    # ---- Eq. 4 / Eq. 5: weight-to-conductance mapping constants ----
    @property
    def g0(self) -> float:
        """Scaling factor G0 = (Gmax - Gmin) / (Wmax - Wmin)   (Eq. 4)."""
        return (self.g_max - self.g_min) / (self.w_max - self.w_min)

    @property
    def g_ref(self) -> float:
        """Reference conductance (Eq. 5).

        G_ref = (Wmax·Gmin - Wmin·Gmax) / (Wmax - Wmin); for a symmetric
        weight range this is the mid-point conductance (Gmax+Gmin)/2.
        """
        return (self.w_max * self.g_min - self.w_min * self.g_max) / (
            self.w_max - self.w_min
        )

    def replace(self, **kw) -> "DeviceParams":
        return dataclasses.replace(self, **kw)


def weight_to_conductance(w: jax.Array, dp: DeviceParams) -> jax.Array:
    """Map algorithmic weights onto device conductances (Eq. 4-5):
    G = G0·W + G_ref."""
    return dp.g0 * w + dp.g_ref


def weight_from_conductance(g: jax.Array, dp: DeviceParams) -> jax.Array:
    """Inverse of Eq. 4-5: the algorithmic weight a (possibly drifted or
    stuck) conductance ``g`` reads back as, W = (G - G_ref) / G0.

    The fault model perturbs in conductance space (stuck-at cells pin G to
    G_min/G_max, drift multiplies G) and maps back through this inverse so
    faulty weights land exactly where the device physics says they should.
    """
    return (g - dp.g_ref) / dp.g0


def thermal_noise_rms(g: jax.Array, dp: DeviceParams) -> jax.Array:
    """RMS thermal-noise current of a device with conductance ``g`` (Eq. 1)."""
    return jnp.sqrt(4.0 * BOLTZMANN_K * dp.temperature * g * dp.delta_f)


def column_noise_sigma(sum_g: jax.Array, dp: DeviceParams) -> jax.Array:
    """Std-dev of the summed column noise current.

    Independent Gaussian device noises add in variance (Eq. 11 / denominator
    of Eq. 13): sigma^2 = 4 k T Δf · Σ_i G_i, where ``sum_g`` already contains
    the conductances of every device hanging off the summing node (both the
    signal column and, for differential readout, the reference column).
    """
    return jnp.sqrt(4.0 * BOLTZMANN_K * dp.temperature * dp.delta_f * sum_g)


def snr_db(p_signal: jax.Array, p_noise: jax.Array) -> jax.Array:
    """Signal-to-noise ratio in dB (Eq. 2)."""
    return 10.0 * jnp.log10(p_signal / p_noise)


def column_snr_db(
    z: jax.Array, sum_g: jax.Array, dp: DeviceParams, r_load: float = 1.0
) -> jax.Array:
    """SNR of a column readout given pre-activation ``z`` (Eq. 2-3).

    Signal current is V_r·G0·z (Eq. 12); both powers share the load R so it
    cancels, but we keep it for fidelity with Eq. 3.
    """
    i_sig = dp.v_read * dp.g0 * z
    p_signal = jnp.square(i_sig) * r_load
    p_noise = jnp.square(column_noise_sigma(sum_g, dp)) * r_load
    return snr_db(p_signal, p_noise)


def calibrate_v_read(
    dp: DeviceParams,
    n_rows: int,
    mean_abs_w: float = 0.0,
    beta: float = 1.0,
) -> DeviceParams:
    """Choose V_r so the comparator fires with logistic(beta·z) probability.

    The comparator fire probability is Phi(V_r·G0·z / sigma_col) (Eq. 13).
    Matching logistic(beta·z) ~= Phi(beta·z/1.702) requires

        V_r·G0/sigma = beta/1.702   =>   V_r = beta·sigma_col / (1.702·G0).

    sigma_col uses the *expected* total conductance on the differential pair:
    Σ_i (G_ij + G_ref) ~= n_rows·(E[G] + G_ref) with E[G] = G_ref for
    zero-mean weights (plus a |W| correction term).  This is the knob the
    paper tunes in Fig. 4(c); Δf, G0 and N_col (Fig. 4(d)-(f)) enter through
    ``sigma_col``.
    """
    e_g = dp.g_ref + mean_abs_w * 0.0  # E[G] = G_ref for zero-mean weights
    sum_g = n_rows * (e_g + dp.g_ref)
    sigma = float(
        jnp.sqrt(4.0 * BOLTZMANN_K * dp.temperature * dp.delta_f * sum_g)
    )
    v_read = beta * sigma / (PROBIT_SCALE * dp.g0)
    return dp.replace(v_read=v_read)


def effective_beta(dp: DeviceParams, n_rows: int) -> float:
    """Inverse: the logistic slope realized by a given DeviceParams."""
    sum_g = n_rows * 2.0 * dp.g_ref
    sigma = float(
        jnp.sqrt(4.0 * BOLTZMANN_K * dp.temperature * dp.delta_f * sum_g)
    )
    return dp.v_read * dp.g0 * PROBIT_SCALE / sigma


def sample_noise_current(
    key: jax.Array, sum_g: jax.Array, dp: DeviceParams, shape=None
) -> jax.Array:
    """Draw summed Gaussian thermal-noise current for columns (Eq. 11)."""
    sigma = column_noise_sigma(sum_g, dp)
    if shape is None:
        shape = jnp.shape(sigma)
    return jax.random.normal(key, shape, dtype=jnp.float32) * sigma
