"""Binary stochastic Sigmoid neurons (paper §III-A, Eq. 8-13).

A comparator on the noisy differential column current fires with probability

    P(I_j > I_ref) = Phi( V_r·G0·z_j / sigma_col )            (Eq. 13)
                   ~= logistic(z_j)        after SNR calibration,

which is exactly the stochastic binarization rule of SBNNs (Eq. 8) with the
sigmoid as activation probability.  Two forward paths are provided:

* ``physical``  — full circuit simulation through crossbar.analog_mac
                  (quantization, per-column ΣG noise, comparator).
* ``calibrated``— the ideal limit P = logistic(beta·z); used as oracle in
                  tests and as the cheap path in large-scale training.

Both are wrapped in a straight-through estimator so the layers are trainable:
forward emits the hard Bernoulli sample, backward uses d/dz E[y] =
sigmoid'(z) — the standard SBNN surrogate the paper inherits ([20],[21]).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import crossbar
from .physics import DeviceParams, PROBIT_SCALE, column_noise_sigma


def fire_probability_physical(
    z: jax.Array, sum_g: jax.Array, dp: DeviceParams
) -> jax.Array:
    """Exact comparator fire probability Phi(V_r·G0·z / sigma) (Eq. 13)."""
    sigma = column_noise_sigma(sum_g, dp)
    arg = dp.v_read * dp.g0 * z / sigma
    return 0.5 * (1.0 + jax.scipy.special.erf(arg / jnp.sqrt(2.0)))


def fire_probability_calibrated(z: jax.Array, beta: float = 1.0) -> jax.Array:
    """The logistic limit the circuit is tuned to (right side of Eq. 13)."""
    return jax.nn.sigmoid(beta * z)


# ---------------------------------------------------------------------------
# Straight-through stochastic binarization.
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def stochastic_binarize(key: jax.Array, p: jax.Array, hard: bool = True):
    """Sample y ~ Bernoulli(p); gradient flows as if y == p (STE).

    ``p`` is the fire probability (any of the paths above).  With
    ``hard=False`` returns p itself (expectation propagation — used for
    deterministic eval)."""
    u = jax.random.uniform(key, p.shape, dtype=p.dtype)
    y = (u < p).astype(p.dtype)
    return y if hard else p


def _binarize_fwd(key, p, hard):
    y = stochastic_binarize(key, p, hard)
    return y, None


def _binarize_bwd(hard, _, g):
    # dE[y]/dp = 1  =>  pass gradient straight through to p.
    return (None, g)


stochastic_binarize.defvjp(_binarize_fwd, _binarize_bwd)


def sigmoid_neuron_calibrated(
    key: jax.Array,
    z: jax.Array,
    beta: float = 1.0,
    hard: bool = True,
) -> jax.Array:
    """Calibrated-limit stochastic sigmoid neuron: y ~ Bern(logistic(beta z))."""
    return stochastic_binarize(key, fire_probability_calibrated(z, beta), hard)


def sigmoid_neuron_physical(
    key: jax.Array,
    x: jax.Array,
    w: jax.Array,
    dp: DeviceParams,
    map_key: Optional[jax.Array] = None,
    hard: bool = True,
) -> jax.Array:
    """Full-circuit stochastic sigmoid neuron layer.

    x: (..., in) inputs (binary {0,1} for hidden layers — DAC-free — or
    continuous in [0,1] for the input layer, which keeps its DAC per §III-C).
    w: (in, out).  Returns binary activations (..., out).

    Rather than thresholding one concrete noisy sample inside the STE (which
    would hide the noise from the gradient), we compute the *exact* fire
    probability of the comparator (Eq. 13 with the true per-column ΣG) and
    sample through the STE — distributionally identical, trainable.
    """
    mapping = crossbar.map_weights(w, dp, key=map_key)
    z = x.astype(jnp.float32) @ mapping.w_eff
    sum_g = crossbar.column_sum_g(mapping)
    p = fire_probability_physical(z, sum_g, dp)
    return stochastic_binarize(key, p, hard)


def comparator_sample(
    key: jax.Array, x: jax.Array, w: jax.Array, dp: DeviceParams
) -> jax.Array:
    """Literal circuit path (no STE): sample currents, compare (Eq. 8-11).

    Used by tests to verify that the STE path above is distributionally
    identical to the physical comparator."""
    mapping = crossbar.map_weights(w, dp)
    delta_i, _ = crossbar.analog_mac(key, x, mapping, dp)
    return (delta_i > 0.0).astype(jnp.float32)
