"""RACA core: the paper's contribution as composable JAX modules.

Public API:
    physics      — Nyquist noise, SNR, calibration (Eq. 1-3, 13)
    crossbar     — weight→conductance mapping, analog MAC (Eq. 4-7, 9-12)
    neurons      — binary stochastic Sigmoid neurons + STE (Eq. 8, 13)
    wta          — WTA binary stochastic SoftMax neurons (Eq. 14)
    analog       — AnalogConfig + mode-dispatched dense/matmul/heads
    cost_model   — NeuroSim-style energy/area model (Table I)
"""

from . import analog, cost_model, crossbar, neurons, physics, wta
from .analog import DIGITAL, AnalogConfig, analog_dense, analog_matmul, wta_head
from .physics import DeviceParams, calibrate_v_read, effective_beta

__all__ = [
    "analog",
    "cost_model",
    "crossbar",
    "neurons",
    "physics",
    "wta",
    "AnalogConfig",
    "DIGITAL",
    "DeviceParams",
    "analog_dense",
    "analog_matmul",
    "wta_head",
    "calibrate_v_read",
    "effective_beta",
]
