"""Continuous-batching scheduler: request lifecycle + slot bookkeeping.

Pure host-side logic (no JAX) so it unit-tests in microseconds.  The engine
owns the device state (decode cache, token buffer, per-slot PRNG keys); this
module owns *which request lives in which slot and when*:

    QUEUED ──admit──▶ PREFILL ──start_decode──▶ DECODE ──evict──▶ DONE
       ▲  priority-ordered,                        │ EOS hit, budget,
       └─ into the lowest free slot                │ deadline, NaN, or
          (mid-flight refill;                      ▼ preemption kill
          requeue() puts a preempted                 frees the slot
          request back at its class head)

Admission is priority-ordered (lower ``priority`` wins; rid breaks ties, so
traffic of a single class is strictly FIFO over submit order); a freed slot
is refilled from the queue head on the next ``admit()`` call, while the
other slots keep decoding — that mid-flight refill is what lifts slot
occupancy over static batching on mixed-length traces.  A preempted request
leaves its slot via :meth:`requeue` (back to QUEUED, same rid — so it heads
its class) and a queued request can be killed without ever owning a slot
via :meth:`cancel`; :meth:`expired` is the deadline view the engine's
deadline pass evicts from.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import hashlib
from typing import Any, Callable, Optional, Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


# Priority classes: LOWER values are MORE urgent.  Interactive traffic
# (chat turns, short completions) overtakes batch jobs at admission and may
# preempt them when the block pool is exhausted.
PRIORITY_INTERACTIVE = 0
PRIORITY_BATCH = 1

# Every terminal ``done_reason`` the scheduler/engine can stamp.  "eos" and
# "length" are natural completions; the rest are evictions: a missed
# deadline, a logit-sanity trip ("nan" non-finite, "saturated" finite but
# over the analog rail, "entropy_collapse" distribution pinned to one
# token — the detection codes of the degraded-device loop), or an
# injected/administrative kill.
EVICT_REASONS = (
    "eos", "length", "deadline", "nan", "saturated", "entropy_collapse",
    "preempted",
)


def left_pad(prompt: Sequence[int], length: int, pad: int = 0) -> list[int]:
    """Right-align ``prompt`` in a window of ``length`` (pad on the left).

    Left padding keeps the last prompt token — the one whose logits seed
    decoding — at a fixed position, so prefill of a short prompt and a long
    prompt produce caches with the same alignment contract.
    """
    if len(prompt) > length:
        raise ValueError(f"prompt len {len(prompt)} > window {length}")
    return [pad] * (length - len(prompt)) + list(prompt)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None         # live binding; None once DONE
    output: list[int] = dataclasses.field(default_factory=list)
    done_reason: Optional[str] = None  # "eos" | "length"
    # the slot this request occupied while live, recorded at eviction —
    # the historical value for metrics/debugging.  ``slot`` itself is
    # nulled when the request leaves its slot, so a late reader can never
    # silently index per-slot state that now belongs to the NEXT request
    # admitted into the same slot.
    done_slot: Optional[int] = None
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    # scheduling class: lower is more urgent (PRIORITY_INTERACTIVE beats
    # PRIORITY_BATCH at admission and may preempt it under pool pressure)
    priority: int = PRIORITY_BATCH
    # wall-clock completion SLO in milliseconds from submit_time; None
    # disables the deadline pass for this request
    deadline_ms: Optional[float] = None
    # how many times this request was preempted (spilled + requeued)
    preemptions: int = 0
    # self-speculative decoding state (paged engine, speculate_k > 0):
    # draft tokens this request's slot put through acceptance, how many
    # were accepted verbatim, and the dirty high-water mark — the highest
    # absolute position a draft run has WRITTEN K/V into, which may run
    # ahead of ``pos`` after a rejection (those rows are masked dead
    # weight until decode reaches them again); always within the
    # request's block reservation plus the trash page
    spec_drafted: int = 0
    spec_accepted: int = 0
    spec_high: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


def prefix_block_hashes(
    padded_prompt: Sequence[int], block_size: int
) -> list[tuple[bytes, int]]:
    """Chain hashes of a padded prompt's KV blocks.

    Block ``i`` of a paged cache holds logical positions
    ``[i·block_size, (i+1)·block_size)``, so its K/V content is fully
    determined by the padded prompt tokens up to and including that block
    (positions are absolute — RoPE makes content position-dependent).  The
    chain digest ``h_i = H(h_{i-1} || n_tokens || tokens_i)`` therefore
    identifies *content at position*: two requests share block ``i`` iff
    their padded prompts agree on every token before ``(i+1)·block_size``.
    The trailing block of an unaligned prompt hashes only the tokens it
    actually holds (``n_tokens`` disambiguates it from a full block).

    Returns one ``(digest, seed)`` pair per block covering the padded
    prompt; ``seed`` is a uint32 derived from the digest, used as the
    canonical stochastic-rounding seed when the block is quantized to int8
    (content-derived, NOT request-derived, so re-prefills of the same
    prefix produce bit-identical codes and the blocks stay shareable).
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    out: list[tuple[bytes, int]] = []
    h = b"raca-prefix-v1"
    n = len(padded_prompt)
    for start in range(0, n, block_size):
        toks = padded_prompt[start : start + block_size]
        m = hashlib.blake2b(digest_size=16)
        m.update(h)
        m.update(len(toks).to_bytes(4, "little"))
        for t in toks:
            m.update(int(t).to_bytes(8, "little", signed=True))
        h = m.digest()
        out.append((h, int.from_bytes(h[:4], "little")))
    return out


class BlockAllocator:
    """Refcounted free-list allocator over a fixed pool of KV-cache blocks,
    with a content-hash prefix index for block sharing.

    Pure host bookkeeping for the paged cache: the engine reserves a
    request's whole block budget at admission (prefill blocks + decode
    budget blocks, so a decoding request can never run out mid-flight) and
    releases it on eviction.  Block 0 is reserved as the *trash page*:
    evicted slots' table rows point at it, so the decode step's writes from
    idle slots land somewhere no live request ever reads.

    Prefix sharing: an allocated page may be *registered* under the chain
    hash of the prompt block it holds (:func:`prefix_block_hashes`).  A
    later admission whose prompt chain matches maps the resident page into
    its own table (``reserve(shared=...)`` bumps the refcount) instead of
    taking a fresh page.  Pages return to the free list only when their
    refcount reaches zero, at which point their index entry (and any
    payload attached to it) is dropped — the index can never hand out a
    freed or recycled page.  A ``spare`` page can be reserved alongside as
    the copy-on-write fork target for a shared block the request will
    write into (:meth:`cow_fork`).

    Index entries may carry an opaque ``payload`` (the engine stores the
    original prefill's last-token logits + per-slot state leaves there, so
    a full-prompt hit can skip its prefill entirely); the allocator never
    inspects payloads, keeping this module host-only logic.
    """

    def __init__(self, n_blocks: int, n_reserved: int = 1):
        if n_blocks <= n_reserved:
            raise ValueError(
                f"pool of {n_blocks} blocks leaves nothing to allocate "
                f"after {n_reserved} reserved"
            )
        self.n_blocks = n_blocks
        self.n_reserved = n_reserved
        # pop() from the tail → lowest-numbered pages are handed out first
        self._free = list(range(n_blocks - 1, n_reserved - 1, -1))
        self._refs: dict[int, int] = {}          # page -> refcount (>= 1)
        self._owned: dict[int, list[int]] = {}   # owner -> mapped pages
        self._spare: dict[int, list[int]] = {}   # owner -> COW fork targets
        self._prefix: dict[bytes, int] = {}      # chain hash -> page
        self._page_hash: dict[int, bytes] = {}   # page -> its chain hash
        self._payload: dict[bytes, Any] = {}     # chain hash -> opaque data

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes reserved pages)."""
        return self.n_blocks - self.n_reserved

    @property
    def available(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def refcount(self, page: int) -> int:
        """How many owners reference ``page`` (0 = free/reserved)."""
        return self._refs.get(page, 0)

    def reserve(
        self,
        owner: int,
        n_new: int,
        shared: Sequence[int] = (),
        n_spare: int = 0,
    ) -> list[int]:
        """Atomically take a request's whole block budget at admission.

        ``shared`` pages (matched through the prefix index) get a refcount
        bump and lead the owner's mapped list, in table order; ``n_new``
        fresh pages follow; ``n_spare`` additional fresh pages are held
        unmapped as guaranteed COW fork targets.  Either everything is
        taken or nothing is (pool exhaustion raises before any state
        changes), so an admission gate's True answer can never leak a
        partial reservation.  Returns the mapped pages (shared + fresh).
        """
        if n_new < 0 or n_spare < 0:
            raise ValueError(f"negative reservation ({n_new}, {n_spare})")
        if not shared and n_new + n_spare < 1:
            raise ValueError("empty reservation")
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds blocks")
        if n_new + n_spare > len(self._free):
            raise ValueError(
                f"pool exhausted: want {n_new + n_spare}, "
                f"have {len(self._free)}"
            )
        if len(set(shared)) != len(shared):
            # a duplicated shared page would be double-mapped into one
            # owner's table AND double-refcounted — free() would then
            # decref it twice for a single logical mapping
            dupes = sorted(
                {p for p in shared if list(shared).count(p) > 1}
            )
            raise ValueError(f"duplicate shared page(s) {dupes}")
        for p in shared:
            if p not in self._refs:
                raise ValueError(f"cannot share unallocated page {p}")
        for p in shared:
            self._refs[p] += 1
        fresh = [self._free.pop() for _ in range(n_new)]
        spare = [self._free.pop() for _ in range(n_spare)]
        for p in fresh + spare:
            self._refs[p] = 1
        self._owned[owner] = list(shared) + fresh
        self._spare[owner] = spare
        return list(self._owned[owner])

    def alloc(self, owner: int, n: int) -> list[int]:
        """Take ``n`` fresh blocks for ``owner`` (the no-sharing path)."""
        if n < 1:
            raise ValueError(f"need at least one block, got {n}")
        return self.reserve(owner, n)

    def _decref(self, page: int) -> bool:
        """Drop one reference; True if the page went back to the free list."""
        self._refs[page] -= 1
        if self._refs[page] > 0:
            return False
        del self._refs[page]
        self.deregister(page)
        self._free.append(page)
        return True

    def free(self, owner: int) -> int:
        """Release ``owner``'s references (mapped + spare pages).

        Returns how many pages actually went back to the pool — shared
        pages survive until their LAST owner releases them (refcount
        zero), which is the whole point of refcounting.
        """
        pages = self._owned.pop(owner)
        pages = pages + self._spare.pop(owner, [])
        return sum(self._decref(p) for p in reversed(pages))

    def owned(self, owner: int) -> list[int]:
        return list(self._owned.get(owner, []))

    def spare_count(self, owner: int) -> int:
        return len(self._spare.get(owner, []))

    def cow_fork(self, owner: int, idx: int) -> tuple[int, int]:
        """Repoint ``owner``'s mapped block ``idx`` at a reserved spare page.

        The copy-on-write fork: called by the engine just before ``owner``
        first writes into a block it shares.  The old page loses one
        reference (it stays alive for — and registered to — its other
        owners); the spare becomes the private replacement.  Returns
        ``(old_page, new_page)`` so the engine can issue the device-side
        page copy and repoint its table row.
        """
        old = self._owned[owner][idx]
        if self._refs.get(old, 0) < 2:
            raise ValueError(
                f"COW fork of page {old} with refcount "
                f"{self._refs.get(old, 0)} — nothing is shared"
            )
        if not self._spare.get(owner):
            raise ValueError(f"owner {owner} reserved no spare fork page")
        new = self._spare[owner].pop()
        self._owned[owner][idx] = new
        self._refs[old] -= 1
        return old, new

    # -- content-hash prefix index ------------------------------------------

    def register(self, page: int, h: bytes, payload: Any = None) -> None:
        """Publish ``page`` as holding the prompt block with chain hash
        ``h``; later admissions matching ``h`` share it via ``reserve``."""
        if page not in self._refs:
            raise ValueError(f"cannot register unallocated page {page}")
        if h in self._prefix:
            raise ValueError(f"hash already registered to page {self._prefix[h]}")
        if page in self._page_hash:
            raise ValueError(f"page {page} already registered")
        self._prefix[h] = page
        self._page_hash[page] = h
        if payload is not None:
            self._payload[h] = payload

    def lookup(self, h: bytes) -> Optional[int]:
        """Resident page holding the block hashed ``h``, or None."""
        return self._prefix.get(h)

    def longest_prefix_match(self, hashes: Sequence[bytes]) -> list[int]:
        """Deepest resident chain hit for a prompt's block hashes.

        Walks ``hashes`` (one chain digest per prompt block, in table
        order) and returns the pages of the longest *consecutive* leading
        run that is resident in the prefix index — the match an admission
        maps into its block table.  Chain digests make consecutiveness
        structural (block ``i``'s hash commits to everything before it),
        so the first miss ends the usable prefix.  Read-only: probing
        never bumps a refcount or touches the index — only a subsequent
        ``reserve(shared=...)`` takes references, and atomically.
        """
        pages: list[int] = []
        for h in hashes:
            page = self._prefix.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def payload(self, h: bytes) -> Any:
        return self._payload.get(h)

    def set_payload(self, h: bytes, payload: Any) -> None:
        if h not in self._prefix:
            raise ValueError("cannot attach payload to unregistered hash")
        self._payload[h] = payload

    def deregister(self, page: int) -> None:
        """Drop ``page``'s index entry (content diverged or page freed).

        Idempotent: unregistered pages are a no-op, so the engine can call
        it unconditionally before an in-place write.
        """
        h = self._page_hash.pop(page, None)
        if h is not None:
            self._prefix.pop(h, None)
            self._payload.pop(h, None)

    def registered_pages(self) -> dict[int, bytes]:
        """page -> hash view of the prefix index (tests/debugging)."""
        return dict(self._page_hash)


class Scheduler:
    """Slot table + FIFO queue; single-threaded, driven by the engine."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._queue: collections.deque[Request] = collections.deque()
        self._slots: list[Optional[Request]] = [None] * n_slots
        self._requests: dict[int, Request] = {}
        self._next_rid = 0

    # -- submission / admission --------------------------------------------

    def submit(
        self,
        prompt: Sequence[int],
        max_new_tokens: int,
        now: float = 0.0,
        priority: int = PRIORITY_BATCH,
        deadline_ms: Optional[float] = None,
    ) -> Request:
        req = Request(
            rid=self._next_rid,
            prompt=list(prompt),
            max_new_tokens=int(max_new_tokens),
            submit_time=now,
            priority=int(priority),
            deadline_ms=None if deadline_ms is None else float(deadline_ms),
        )
        self._next_rid += 1
        self._requests[req.rid] = req
        self._queue.append(req)
        return req

    def peek(self) -> Optional[Request]:
        """The request :meth:`admit` would try next (priority head)."""
        if not self._queue:
            return None
        return min(self._queue, key=lambda r: (r.priority, r.rid))

    def admit(
        self,
        gate: Optional[Callable[[Request], bool]] = None,
        shed_priority_above: Optional[int] = None,
    ) -> list[Request]:
        """Move queued requests into free slots (priority order, lowest
        slot first).

        The queue head is the most-urgent queued request — lowest
        ``priority``, rid breaking ties, so single-class traffic is
        strictly FIFO and a requeued (preempted) request resumes at the
        head of its class.  ``gate``, when given, is asked per queue-head
        request whether it can be admitted right now (the paged engine's
        block-pool back-pressure).  A gated-out head STOPS admission —
        skipping ahead would break the ordering and could starve large
        requests behind a stream of small ones.  The request simply stays
        QUEUED for a later ``admit()``.

        ``shed_priority_above``, when given, refuses admission to any head
        whose priority is strictly less urgent (numerically greater) —
        the degradation ladder's load-shedding rung: under sustained fault
        pressure batch-class traffic waits in queue while interactive
        traffic keeps flowing.  Because the head is the MOST urgent queued
        request, stopping at a shed head never skips an admissible one.

        Returns the newly admitted requests, now in PREFILL state; the
        engine must prefill each and call :meth:`start_decode`.
        """
        admitted = []
        for slot in range(self.n_slots):
            if not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            head = min(self._queue, key=lambda r: (r.priority, r.rid))
            if (
                shed_priority_above is not None
                and head.priority > shed_priority_above
            ):
                break
            if gate is not None and not gate(head):
                break
            self._queue.remove(head)
            head.state = RequestState.PREFILL
            head.slot = slot
            self._slots[slot] = head
            admitted.append(head)
        return admitted

    def start_decode(self, req: Request) -> None:
        assert req.state is RequestState.PREFILL, req.state
        req.state = RequestState.DECODE

    # -- token accounting / eviction ---------------------------------------

    def record_token(
        self, req: Request, token: int, eos_token: int, now: float = 0.0
    ) -> bool:
        """Append one generated token; evict on EOS / length.  True if done.

        ``eos_token < 0`` (the default -1) disables early stopping — real
        token ids are non-negative, so -1 can never match.
        """
        assert req.state is RequestState.DECODE, req.state
        if req.first_token_time is None:
            req.first_token_time = now
        req.output.append(int(token))
        if eos_token >= 0 and int(token) == eos_token:
            self.evict(req, "eos", now)
            return True
        if len(req.output) >= req.max_new_tokens:
            self.evict(req, "length", now)
            return True
        return False

    def evict(self, req: Request, reason: str, now: float = 0.0) -> None:
        assert req.slot is not None
        self._slots[req.slot] = None
        req.state = RequestState.DONE
        req.done_reason = reason
        req.done_time = now
        # sever the live slot binding: the next admission reuses this
        # slot, and a DONE request that kept aliasing it would let any
        # late reader (metrics, debug hooks, sharded transfer paths)
        # index ANOTHER request's per-slot state.  The historical slot
        # stays available as done_slot.
        req.done_slot = req.slot
        req.slot = None

    def requeue(self, req: Request) -> None:
        """Preempt a slotted request back to QUEUED (slot freed, output and
        timing kept).

        The rid is unchanged, so the priority queue puts the request back
        at the head of its class — a preempted request is never overtaken
        by later arrivals of the same priority.  The engine is responsible
        for spilling/freeing the request's device state before calling
        this.
        """
        assert req.slot is not None, "only a slotted request can be requeued"
        assert req.state in (RequestState.PREFILL, RequestState.DECODE)
        self._slots[req.slot] = None
        req.slot = None
        req.state = RequestState.QUEUED
        req.preemptions += 1
        self._queue.append(req)

    def cancel(self, req: Request, reason: str, now: float = 0.0) -> None:
        """Kill a QUEUED request that never got (or no longer holds) a slot."""
        assert req.state is RequestState.QUEUED, req.state
        self._queue.remove(req)
        req.state = RequestState.DONE
        req.done_reason = reason
        req.done_time = now

    def expired(self, now: float) -> list[Request]:
        """Live requests whose deadline has passed, in rid order."""
        out = [
            r
            for r in self._requests.values()
            if r.state is not RequestState.DONE
            and r.deadline_ms is not None
            and (now - r.submit_time) * 1e3 > r.deadline_ms
        ]
        return sorted(out, key=lambda r: r.rid)

    # -- views --------------------------------------------------------------

    def active(self) -> list[Request]:
        """Requests currently decoding, in slot order."""
        return [
            r
            for r in self._slots
            if r is not None and r.state is RequestState.DECODE
        ]

    def occupancy(self) -> float:
        return sum(r is not None for r in self._slots) / self.n_slots

    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slots
        )

    def queued(self) -> int:
        return len(self._queue)

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def all_requests(self) -> list[Request]:
        """Every request ever submitted, in submission (rid) order."""
        return [self._requests[rid] for rid in sorted(self._requests)]
