"""Continuous-batching scheduler: request lifecycle + slot bookkeeping.

Pure host-side logic (no JAX) so it unit-tests in microseconds.  The engine
owns the device state (decode cache, token buffer, per-slot PRNG keys); this
module owns *which request lives in which slot and when*:

    QUEUED ──admit──▶ PREFILL ──start_decode──▶ DECODE ──evict──▶ DONE
       ▲  FIFO, into the                           │ EOS hit or
       └─ lowest free slot                         │ max_new_tokens
          (mid-flight refill)                      ▼ frees the slot

Admission is strictly FIFO over the submit order; a freed slot is refilled
from the queue head on the next ``admit()`` call, while the other slots keep
decoding — that mid-flight refill is what lifts slot occupancy over static
batching on mixed-length traces.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Callable, Optional, Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


def left_pad(prompt: Sequence[int], length: int, pad: int = 0) -> list[int]:
    """Right-align ``prompt`` in a window of ``length`` (pad on the left).

    Left padding keeps the last prompt token — the one whose logits seed
    decoding — at a fixed position, so prefill of a short prompt and a long
    prompt produce caches with the same alignment contract.
    """
    if len(prompt) > length:
        raise ValueError(f"prompt len {len(prompt)} > window {length}")
    return [pad] * (length - len(prompt)) + list(prompt)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    output: list[int] = dataclasses.field(default_factory=list)
    done_reason: Optional[str] = None  # "eos" | "length"
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV-cache blocks.

    Pure host bookkeeping for the paged cache: the engine asks for a
    request's whole block budget at admission (prefill blocks + decode
    budget blocks, so a decoding request can never run out mid-flight) and
    returns them on eviction.  Block 0 is reserved as the *trash page*:
    evicted slots' table rows point at it, so the decode step's writes from
    idle slots land somewhere no live request ever reads.
    """

    def __init__(self, n_blocks: int, n_reserved: int = 1):
        if n_blocks <= n_reserved:
            raise ValueError(
                f"pool of {n_blocks} blocks leaves nothing to allocate "
                f"after {n_reserved} reserved"
            )
        self.n_blocks = n_blocks
        self.n_reserved = n_reserved
        # pop() from the tail → lowest-numbered pages are handed out first
        self._free = list(range(n_blocks - 1, n_reserved - 1, -1))
        self._owned: dict[int, list[int]] = {}

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes reserved pages)."""
        return self.n_blocks - self.n_reserved

    @property
    def available(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, owner: int, n: int) -> list[int]:
        """Take ``n`` blocks for ``owner`` (a request id)."""
        if n < 1:
            raise ValueError(f"need at least one block, got {n}")
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds blocks")
        if n > len(self._free):
            raise ValueError(
                f"pool exhausted: want {n}, have {len(self._free)}"
            )
        blocks = [self._free.pop() for _ in range(n)]
        self._owned[owner] = blocks
        return list(blocks)

    def free(self, owner: int) -> int:
        """Return ``owner``'s blocks to the pool; returns how many."""
        blocks = self._owned.pop(owner)
        self._free.extend(reversed(blocks))
        return len(blocks)

    def owned(self, owner: int) -> list[int]:
        return list(self._owned.get(owner, []))


class Scheduler:
    """Slot table + FIFO queue; single-threaded, driven by the engine."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._queue: collections.deque[Request] = collections.deque()
        self._slots: list[Optional[Request]] = [None] * n_slots
        self._requests: dict[int, Request] = {}
        self._next_rid = 0

    # -- submission / admission --------------------------------------------

    def submit(
        self, prompt: Sequence[int], max_new_tokens: int, now: float = 0.0
    ) -> Request:
        req = Request(
            rid=self._next_rid,
            prompt=list(prompt),
            max_new_tokens=int(max_new_tokens),
            submit_time=now,
        )
        self._next_rid += 1
        self._requests[req.rid] = req
        self._queue.append(req)
        return req

    def admit(
        self, gate: Optional[Callable[[Request], bool]] = None
    ) -> list[Request]:
        """Move queued requests into free slots (FIFO, lowest slot first).

        ``gate``, when given, is asked per queue-head request whether it can
        be admitted right now (the paged engine's block-pool back-pressure).
        A gated-out head STOPS admission — skipping ahead would break FIFO
        and could starve large requests behind a stream of small ones.  The
        request simply stays QUEUED for a later ``admit()``.

        Returns the newly admitted requests, now in PREFILL state; the
        engine must prefill each and call :meth:`start_decode`.
        """
        admitted = []
        for slot in range(self.n_slots):
            if not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            if gate is not None and not gate(self._queue[0]):
                break
            req = self._queue.popleft()
            req.state = RequestState.PREFILL
            req.slot = slot
            self._slots[slot] = req
            admitted.append(req)
        return admitted

    def start_decode(self, req: Request) -> None:
        assert req.state is RequestState.PREFILL, req.state
        req.state = RequestState.DECODE

    # -- token accounting / eviction ---------------------------------------

    def record_token(
        self, req: Request, token: int, eos_token: int, now: float = 0.0
    ) -> bool:
        """Append one generated token; evict on EOS / length.  True if done.

        ``eos_token < 0`` (the default -1) disables early stopping — real
        token ids are non-negative, so -1 can never match.
        """
        assert req.state is RequestState.DECODE, req.state
        if req.first_token_time is None:
            req.first_token_time = now
        req.output.append(int(token))
        if eos_token >= 0 and int(token) == eos_token:
            self.evict(req, "eos", now)
            return True
        if len(req.output) >= req.max_new_tokens:
            self.evict(req, "length", now)
            return True
        return False

    def evict(self, req: Request, reason: str, now: float = 0.0) -> None:
        assert req.slot is not None
        self._slots[req.slot] = None
        req.state = RequestState.DONE
        req.done_reason = reason
        req.done_time = now

    # -- views --------------------------------------------------------------

    def active(self) -> list[Request]:
        """Requests currently decoding, in slot order."""
        return [
            r
            for r in self._slots
            if r is not None and r.state is RequestState.DECODE
        ]

    def occupancy(self) -> float:
        return sum(r is not None for r in self._slots) / self.n_slots

    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slots
        )

    def queued(self) -> int:
        return len(self._queue)

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def all_requests(self) -> list[Request]:
        """Every request ever submitted, in submission (rid) order."""
        return [self._requests[rid] for rid in sorted(self._requests)]
