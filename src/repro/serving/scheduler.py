"""Continuous-batching scheduler: request lifecycle + slot bookkeeping.

Pure host-side logic (no JAX) so it unit-tests in microseconds.  The engine
owns the device state (decode cache, token buffer, per-slot PRNG keys); this
module owns *which request lives in which slot and when*:

    QUEUED ──admit──▶ PREFILL ──start_decode──▶ DECODE ──evict──▶ DONE
       ▲  FIFO, into the                           │ EOS hit or
       └─ lowest free slot                         │ max_new_tokens
          (mid-flight refill)                      ▼ frees the slot

Admission is strictly FIFO over the submit order; a freed slot is refilled
from the queue head on the next ``admit()`` call, while the other slots keep
decoding — that mid-flight refill is what lifts slot occupancy over static
batching on mixed-length traces.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Optional, Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


def left_pad(prompt: Sequence[int], length: int, pad: int = 0) -> list[int]:
    """Right-align ``prompt`` in a window of ``length`` (pad on the left).

    Left padding keeps the last prompt token — the one whose logits seed
    decoding — at a fixed position, so prefill of a short prompt and a long
    prompt produce caches with the same alignment contract.
    """
    if len(prompt) > length:
        raise ValueError(f"prompt len {len(prompt)} > window {length}")
    return [pad] * (length - len(prompt)) + list(prompt)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    output: list[int] = dataclasses.field(default_factory=list)
    done_reason: Optional[str] = None  # "eos" | "length"
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


class Scheduler:
    """Slot table + FIFO queue; single-threaded, driven by the engine."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._queue: collections.deque[Request] = collections.deque()
        self._slots: list[Optional[Request]] = [None] * n_slots
        self._requests: dict[int, Request] = {}
        self._next_rid = 0

    # -- submission / admission --------------------------------------------

    def submit(
        self, prompt: Sequence[int], max_new_tokens: int, now: float = 0.0
    ) -> Request:
        req = Request(
            rid=self._next_rid,
            prompt=list(prompt),
            max_new_tokens=int(max_new_tokens),
            submit_time=now,
        )
        self._next_rid += 1
        self._requests[req.rid] = req
        self._queue.append(req)
        return req

    def admit(self) -> list[Request]:
        """Move queued requests into free slots (FIFO, lowest slot first).

        Returns the newly admitted requests, now in PREFILL state; the
        engine must prefill each and call :meth:`start_decode`.
        """
        admitted = []
        for slot in range(self.n_slots):
            if not self._queue:
                break
            if self._slots[slot] is not None:
                continue
            req = self._queue.popleft()
            req.state = RequestState.PREFILL
            req.slot = slot
            self._slots[slot] = req
            admitted.append(req)
        return admitted

    def start_decode(self, req: Request) -> None:
        assert req.state is RequestState.PREFILL, req.state
        req.state = RequestState.DECODE

    # -- token accounting / eviction ---------------------------------------

    def record_token(
        self, req: Request, token: int, eos_token: int, now: float = 0.0
    ) -> bool:
        """Append one generated token; evict on EOS / length.  True if done.

        ``eos_token < 0`` (the default -1) disables early stopping — real
        token ids are non-negative, so -1 can never match.
        """
        assert req.state is RequestState.DECODE, req.state
        if req.first_token_time is None:
            req.first_token_time = now
        req.output.append(int(token))
        if eos_token >= 0 and int(token) == eos_token:
            self.evict(req, "eos", now)
            return True
        if len(req.output) >= req.max_new_tokens:
            self.evict(req, "length", now)
            return True
        return False

    def evict(self, req: Request, reason: str, now: float = 0.0) -> None:
        assert req.slot is not None
        self._slots[req.slot] = None
        req.state = RequestState.DONE
        req.done_reason = reason
        req.done_time = now

    # -- views --------------------------------------------------------------

    def active(self) -> list[Request]:
        """Requests currently decoding, in slot order."""
        return [
            r
            for r in self._slots
            if r is not None and r.state is RequestState.DECODE
        ]

    def occupancy(self) -> float:
        return sum(r is not None for r in self._slots) / self.n_slots

    def has_work(self) -> bool:
        return bool(self._queue) or any(
            r is not None for r in self._slots
        )

    def queued(self) -> int:
        return len(self._queue)

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    def all_requests(self) -> list[Request]:
        """Every request ever submitted, in submission (rid) order."""
        return [self._requests[rid] for rid in sorted(self._requests)]
