"""Serving-engine fault injection: scheduled chaos for the paged engine.

A :class:`FaultInjector` is a :class:`repro.testing.FaultSchedule` plus an
interpreter for serving-specific fault kinds.  Attach one via
``ServeConfig.fault_injector``; the engine calls :meth:`fire` at the start
of every tick and the injector applies whatever events are due.  The
contract under EVERY injected fault: the engine keeps serving, allocator
invariants hold, and every affected request ends with a typed
``done_reason`` (tests/test_faults.py fuzz-checks exactly this).

Fault kinds:

``exhaust_pool``
    Reserve every free block under a sentinel owner — the admission gate
    back-pressures as if live traffic held the pool.  ``release_pool``
    hands it back.
``nan_logits``
    Overwrite one private read-window page of a decoding request
    (``rid=...``, default: any poisonable active request) with
    NaN/zeroed-int content — the paged analogue of an analog path
    emitting garbage.  The next decode step's finite-logits flag drops
    and the engine evicts the victim with reason ``"nan"``.
``deadline_storm``
    Stamp ``deadline_ms`` (default 0: already expired) onto every live
    request — the next deadline pass evicts them all.
``kill_prefill``
    Terminally evict a mid-chunked-prefill request (``rid=...``, default:
    the job FIFO head) with reason ``"preempted"`` — the job leaves the
    pipeline and frees its pages atomically; queued sharers of its
    never-written pages demote to recompute.
``preempt``
    Force a spill-preemption of a decoding request (``rid=...``, default:
    the lowest-priority, newest active) — it requeues and later restores
    through the normal gate.
``degrade_device``
    Degrade the engine's device backend (``sim_faulty``): jump its fault
    clock (``clock=...``) and/or override readout knobs
    (``read_sigma_inflation=...``, ``comparator_offset=...``,
    ``drift_nu=...``).  A no-op on backends without the hook (plain sim),
    so mixed chaos schedules stay valid everywhere.
``recover_device``
    Reset the backend's fault clock and drop the knob overrides
    (retired tiles stay retired — remapping is physical and one-way).

Kinds are validated at :meth:`at` schedule time — a typo'd kind raises
immediately with the registered list instead of exploding at fire time
deep inside a run.

Usage::

    inj = FaultInjector().at(3, "exhaust_pool").at(6, "release_pool")
    engine = ServingEngine(params, mcfg, ServeConfig(fault_injector=inj))
"""

from __future__ import annotations

import time
from typing import Any, Optional

from repro.testing import FaultSchedule

# sentinel BlockAllocator owner for the pool-exhaustion fault; negative so
# it can never collide with a request id
POOL_HOG_OWNER = -1


class FaultInjector(FaultSchedule):
    """Tick-scheduled fault interpreter for :class:`ServingEngine`."""

    def __init__(self) -> None:
        super().__init__()
        self._hogging = False
        # (tick, kind, rid-or-None) log of faults actually APPLIED —
        # distinct from ``fired`` (scheduled events that came due): a
        # nan_logits event with no poisonable victim fires but applies
        # nothing
        self.applied: list[tuple[int, str, Optional[int]]] = []

    @classmethod
    def kinds(cls) -> tuple[str, ...]:
        """Every registered fault kind (the ``_do_*`` method registry)."""
        return tuple(
            sorted(
                name[len("_do_"):]
                for name in dir(cls)
                if name.startswith("_do_")
            )
        )

    def at(self, tick: int, kind: str, **kwargs: Any) -> "FaultInjector":
        """Schedule ``kind`` at ``tick`` — validated HERE, so a typo'd
        kind raises at schedule time with the registered list instead of
        an AttributeError at fire time deep inside a run."""
        if not hasattr(self, f"_do_{kind}"):
            raise ValueError(
                f"unknown fault kind {kind!r}; registered: "
                f"{list(self.kinds())}"
            )
        super().at(tick, kind, **kwargs)
        return self

    def fire(self, engine: Any, tick: int) -> None:
        for ev in self.pop(tick):
            getattr(self, f"_do_{ev.kind}")(engine, tick, **ev.kwargs)

    # -- fault kinds --------------------------------------------------------

    def _do_exhaust_pool(self, engine, tick: int) -> None:
        n = engine.blocks.available
        if self._hogging or n == 0:
            return
        engine.blocks.reserve(POOL_HOG_OWNER, n)
        self._hogging = True
        self.applied.append((tick, "exhaust_pool", None))

    def _do_release_pool(self, engine, tick: int) -> None:
        if not self._hogging:
            return
        engine.blocks.free(POOL_HOG_OWNER)
        self._hogging = False
        self.applied.append((tick, "release_pool", None))

    def _do_nan_logits(self, engine, tick: int, rid: Optional[int] = None) -> None:
        victims = (
            [engine.sched.request(rid)] if rid is not None
            else engine.sched.active()
        )
        for req in victims:
            if req.slot is not None and engine._poison_nan(req):
                self.applied.append((tick, "nan_logits", req.rid))
                return

    def _do_deadline_storm(
        self, engine, tick: int, deadline_ms: float = 0.0
    ) -> None:
        now = time.perf_counter()
        for req in engine.sched.all_requests():
            if req.done_time is None:
                # already-elapsed lifetime counts against the new SLO, so
                # deadline_ms=0 expires everything at the next pass
                req.deadline_ms = (
                    (now - req.submit_time) * 1e3 + float(deadline_ms)
                )
                self.applied.append((tick, "deadline_storm", req.rid))

    def _do_kill_prefill(
        self, engine, tick: int, rid: Optional[int] = None
    ) -> None:
        if rid is None:
            if not engine._job_fifo:
                return
            rid = engine._job_fifo[0]
        req = engine.sched.request(rid)
        engine._evict_request(req, "preempted", time.perf_counter())
        self.applied.append((tick, "kill_prefill", rid))

    def _do_preempt(self, engine, tick: int, rid: Optional[int] = None) -> None:
        if rid is not None:
            victims = [engine.sched.request(rid)]
        else:
            victims = sorted(
                engine.sched.active(),
                key=lambda r: (r.priority, r.rid),
                reverse=True,
            )
        if victims and victims[0].slot is not None:
            engine._preempt(victims[0])
            self.applied.append((tick, "preempt", victims[0].rid))

    def _do_degrade_device(
        self, engine, tick: int, clock: Optional[int] = None, **knobs: Any
    ) -> None:
        bk = getattr(engine, "backend", None)
        if bk is None or not hasattr(bk, "degrade"):
            return  # plain sim backend: device faults don't apply
        bk.degrade(clock=clock, **knobs)
        self.applied.append((tick, "degrade_device", None))

    def _do_recover_device(self, engine, tick: int) -> None:
        bk = getattr(engine, "backend", None)
        if bk is None or not hasattr(bk, "recover"):
            return
        bk.recover()
        self.applied.append((tick, "recover_device", None))
