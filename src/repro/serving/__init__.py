from .engine import (
    DegradationPolicy,
    ServeConfig,
    ServingEngine,
    ServingMetrics,
    StaticServingEngine,
)
from .faults import FaultInjector, POOL_HOG_OWNER
from .scheduler import (
    EVICT_REASONS,
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    BlockAllocator,
    Request,
    RequestState,
    Scheduler,
    left_pad,
    prefix_block_hashes,
)

__all__ = [
    "BlockAllocator",
    "DegradationPolicy",
    "EVICT_REASONS",
    "FaultInjector",
    "POOL_HOG_OWNER",
    "PRIORITY_BATCH",
    "PRIORITY_INTERACTIVE",
    "ServeConfig",
    "ServingEngine",
    "ServingMetrics",
    "StaticServingEngine",
    "Request",
    "RequestState",
    "Scheduler",
    "left_pad",
    "prefix_block_hashes",
]
