from .engine import (
    ServeConfig,
    ServingEngine,
    ServingMetrics,
    StaticServingEngine,
)
from .scheduler import Request, RequestState, Scheduler, left_pad

__all__ = [
    "ServeConfig",
    "ServingEngine",
    "ServingMetrics",
    "StaticServingEngine",
    "Request",
    "RequestState",
    "Scheduler",
    "left_pad",
]
