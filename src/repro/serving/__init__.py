from .engine import (
    ServeConfig,
    ServingEngine,
    ServingMetrics,
    StaticServingEngine,
)
from .scheduler import (
    BlockAllocator,
    Request,
    RequestState,
    Scheduler,
    left_pad,
)

__all__ = [
    "BlockAllocator",
    "ServeConfig",
    "ServingEngine",
    "ServingMetrics",
    "StaticServingEngine",
    "Request",
    "RequestState",
    "Scheduler",
    "left_pad",
]
