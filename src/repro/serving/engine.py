"""Serving engines: continuous batching (default) + static-batch reference.

Serving-side integration of the paper: with ``cfg.wta_head`` the sampler is
the WTA stochastic SoftMax circuit — per emitted token, T comparator-bank
decision trials vote and the majority wins (§III-B/C).  Repeated-vote
majority is exactly the paper's accuracy-recovery mechanism (Fig. 6), here
applied to LM decoding; greedy argmax is the digital baseline.

``ServingEngine`` is a continuous-batching engine: a slot-based scheduler
(`repro.serving.scheduler`) admits queued requests into free slots of a
live decode batch.  Under the paged layout, prefill is a CHUNKED,
INTERLEAVED phase: an admission enqueues a prefill job (prompt left-padded
to a compile-size bucket) and the engine computes at most
``ServeConfig.prefill_chunk`` suffix tokens per tick between batched
decode steps — long prompts never stall the in-flight decodes for more
than one chunk's worth of work, and a partial-prefix hit starts its job
mid-prompt (see below).  The dense layout keeps the monolithic
one-request prefill as the byte-identity oracle.  Finished requests (EOS
or per-request ``max_new_tokens``) are evicted and their slot refilled
mid-flight, which is what lifts slot occupancy over static batching on
mixed-length traces.

The KV cache is **paged** by default (``ServeConfig.kv_layout``): a global
pool of fixed-size blocks plus a per-slot block table, so cache capacity is
shared across slots and a decode step only touches the blocks a request has
actually filled — O(blocks·block_size) attention work per token instead of
O(max_len).  Blocks are taken from a free-list allocator at admission
(covering the whole prompt+budget, so a request can never starve
mid-decode), returned at eviction, and pool exhaustion back-pressures
admission (the queue head waits, FIFO preserved).  ``kv_layout="dense"``
keeps the PR-1 per-slot ``max_len`` window as the equivalence oracle:
greedy decode is byte-identical between the two layouts
(tests/test_serving.py).

With ``ModelConfig.kv_cache_dtype="int8"`` the paged pool holds
stochastically rounded int8 codes + per-(page, slot-in-page, head) scale
planes — half the decode HBM bytes per token, dequant fused into the
paged-attention math, and ``num_kv_blocks`` (a native-dtype memory budget)
buys twice the pages, so admission takes ~2x the requests at equal budget
(docs/serving.md §"Quantized KV pool").

Prefix sharing (``ServeConfig.enable_prefix_sharing``, paged only): each
admission chains content hashes over its padded prompt's blocks and maps
the deepest resident match into its block table (refcount bump in the
allocator's prefix index) instead of re-prefilling it.  A *full* match
skips prefill entirely (first token sampled from the original prefill's
stored last-token logits, stored O(1) state leaves inserted).  A
*partial* match prefills ONLY the suffix: the job starts at the resume
point and its chunks attend into the shared paged K/V through the
prefix-aware chunked-prefill kernel — attention-only families resume at
the full matched block depth, recurrent/SSM families at the deepest chunk
boundary whose state snapshot is stashed in the index.  The first decode
write into a still-shared block copy-on-write forks it onto a spare page
reserved at admission; pages return to the free list only at refcount
zero.  int8 pools stay shareable because block quantization seeds derive
from block CONTENT (chain hash), not the request id (docs/serving.md
§"Prefix sharing & copy-on-write", §"Partial-prefix prefill & chunked
scheduling").

WTA sampling stays independent per request: every slot carries the key
``fold_in(base_key, rid)`` and a step counter, so a request's vote noise is
a function of (its rid, its token index) only — invariant to batch
composition.  ``StaticServingEngine`` keeps the old static-batch semantics
(whole batch prefilled together, slots held until the last request ends) as
the baseline that benchmarks and equivalence tests compare against.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as BK
from repro.kernels import ops as KOPS
from repro.launch import specs as SP
from repro.models import ModelConfig, get_model_fns
from repro.serving.scheduler import (
    BlockAllocator,
    Request,
    RequestState,
    Scheduler,
    left_pad,
    prefix_block_hashes,
)


def _pctl(vals: Sequence[float], q: float) -> float:
    """Percentile helper tolerant of empty samples (metrics views)."""
    return float(np.percentile(np.asarray(vals), q)) if len(vals) else 0.0


def _default_buckets(max_len: int) -> tuple[int, ...]:
    out, b = [], 8
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


@dataclasses.dataclass
class DegradationPolicy:
    """Graceful-degradation ladder under sustained fault pressure.

    The engine tracks *detection events* per tick (canary failures +
    logit-sanity evictions).  ``trip_after`` consecutive dirty ticks
    escalate one rung; ``recover_after`` consecutive clean canary PASSES
    de-escalate one rung (so without a canary configured, degradation is
    one-way — there is no evidence the substrate recovered).  Rungs, in
    order, trade throughput for integrity:

    * level 0 — healthy, all features on;
    * level 1 — speculative decoding disabled (a drafted run multiplies
      the blast radius of one bad logit row by k);
    * level 2 — WTA redundant reads raised to ``redundant_reads``
      (majority voting over comparator re-reads, priced in the energy
      accounting);
    * level 3 — admissions shed: queued requests with priority strictly
      less urgent than ``shed_priority_above`` wait while interactive
      traffic keeps flowing.

    Every transition (either direction) is recorded in
    ``ServingMetrics.degraded_transitions`` with the tick and cause.
    """

    trip_after: int = 2        # consecutive dirty ticks per escalation
    recover_after: int = 3     # consecutive clean canary passes per rung
    redundant_reads: int = 3   # R at level >= 2 (majority vote)
    shed_priority_above: int = 0  # level 3: shed priority > this


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8          # decode slots
    max_new_tokens: int = 32    # default per-request budget
    max_len: int = 512          # per-request capacity (prompt + generated)
    eos_token: int = -1         # -1: never stop early
    seed: int = 0
    # prompt lengths are left-padded up to the next bucket so prefill
    # compiles once per bucket, not once per distinct prompt length.
    prefill_buckets: tuple[int, ...] = ()
    # KV cache layout: "paged" (block pool + per-slot block table, the
    # default) or "dense" (per-slot max_len window, the PR-1 oracle).
    kv_layout: str = "paged"
    kv_block_size: int = 16     # tokens per KV block (paged layout)
    # total pool size in blocks; 0 → dense-parity capacity
    # (max_batch · ceil(max_len / block) + 1 trash block).  Set it lower to
    # shrink cache memory — admission back-pressures when the pool runs dry.
    num_kv_blocks: int = 0
    # paged layout only: admissions match their padded prompt's blocks
    # against resident blocks (content-hash prefix index) and map the hits
    # into their block table instead of re-prefilling; the first write into
    # a still-shared block copy-on-write forks it.  Greedy decode is
    # byte-identical with sharing on vs off (tests/test_serving.py); turn
    # it off to isolate raw pool behavior (capacity benchmarks).
    enable_prefix_sharing: bool = True
    # paged layout only: at most this many prefill tokens are COMPUTED per
    # engine tick, between decode steps — a long prompt prefills as a
    # sequence of suffix chunks while the in-flight slots keep decoding,
    # bounding the decode-latency jitter a monolithic bucket prefill would
    # inject.  0 (the default) computes the whole bucket as one chunk.
    # Must be a positive multiple of kv_block_size when set; chunk
    # boundaries are also the resume grid for partial-prefix hits of
    # recurrent/SSM families (their boundary states are stashed in the
    # prefix index), so smaller chunks = finer-grained prefix reuse for
    # stateful models, at more (bucket, chunk) compile pairs.
    prefill_chunk: int = 0
    # paged layout only: when a higher-priority arrival cannot reserve
    # blocks (or a slot), the engine preempts the lowest-priority DECODING
    # request — its pages spill to a host-side store and the request
    # requeues at the head of its class; restore re-admits through the
    # normal gate (shared prefix pages come back as index hits, the
    # decoded tail scatters back from the spilled payload) and the
    # restored token stream is byte-identical to an un-preempted run.
    # Uniform-priority traffic never preempts (a victim must have STRICTLY
    # lower priority), so the default-on flag is inert for single-class
    # workloads.
    enable_preemption: bool = True
    # optional repro.serving.faults.FaultInjector (paged only): fired at
    # the start of every tick; can exhaust the pool, poison a slot's
    # logits to NaN, storm deadlines, or kill an in-flight prefill.  The
    # chaos harness — None (the default) costs nothing.
    fault_injector: Optional[Any] = None
    # paged layout only: a jax.sharding.Mesh with ("data", "model") axes.
    # When set, the paged pool shards its page axis over data (capacity
    # scales with the data axis at constant per-device memory) and
    # kv_heads over model, per-slot decode inputs shard their slot axis
    # over data, and every device entry point runs with mesh-aware
    # in_shardings/out_shardings — while the block table, BlockAllocator,
    # and the content-hash prefix index stay host-global, so prefix
    # sharing and COW work across shards unchanged.  Non-divisible dims
    # replicate (divisibility guards).  A 1×1 mesh is byte-identical to
    # mesh=None (tests/test_serving.py pins it).
    mesh: Optional[Any] = None
    # paged layout only: self-speculative decoding depth.  k > 0 turns
    # each decode tick into ONE fused draft-k → verify-k device round:
    # every decoding slot drafts k chained tokens (the analog/int8 decode
    # step, K/V written into its reserved pages), then the whole drafted
    # run is re-decoded read-only from the pre-draft state snapshot and
    # accepted up to the first verifier disagreement — which also IS the
    # corrected resample.  Greedy (and per-slot-keyed WTA) streams are
    # byte-identical to speculate_k=0; the win is k tokens per host
    # round-trip instead of one.  A rejected tail rolls pos + recurrent
    # state back through the verifier's per-step states; drafted K/V
    # beyond the rollback point is masked dead rows, overwritten later.
    speculate_k: int = 0
    # paged layout only: bytes cap on the host-side preemption spill
    # store (None = unbounded, the PR-7 behavior).  At the cap the OLDEST
    # records drop first (insertion order — records are only touched
    # again when popped for restore); a dropped record's request
    # re-admits through the normal fresh gate and recomputes its prompt,
    # then teacher-forces its already-published tokens back through the
    # ordinary decode path (deterministic per (key, step), so the
    # recomputed stream is the published one — nothing re-publishes).
    spill_budget_bytes: Optional[int] = None
    # device backend the engine accounts analog events against (see
    # repro.kernels.backend).  "sim" (the default) keeps today's
    # Pallas/jnp math and tallies crossbar/comparator/DAC/rounding event
    # counts per entry-point call, priced into ServingMetrics.analog by
    # the Table I cost model.  Each engine owns a PRIVATE backend
    # instance, so two engines compared side by side never share tallies.
    device_backend: str = "sim"
    # ---- degraded-device serving (see docs/serving.md §"Analog fault
    # model & degraded-mode serving") ----
    # kernels.backend.FaultConfig for device_backend="sim_faulty" — the
    # deterministic stuck-at/drift/read-noise/comparator-offset model.
    # Only valid with the faulty backend (loud otherwise).
    device_fault_config: Optional[Any] = None
    # fire the known-answer canary MAC every N ticks (0 = off); a probe
    # whose relative error vs the host-side answer exceeds
    # canary_threshold counts as a detection event (and triggers tile
    # retirement when tile_retire_threshold > 0)
    canary_interval: int = 0
    canary_threshold: float = 0.05
    # retire (remap-to-spare) crossbar tiles whose stuck-at density
    # crosses this on a canary failure; 0 disables retirement
    tile_retire_threshold: float = 0.0
    # WTA comparator re-reads per sampled token (majority vote); 1 is the
    # plain single-read path, byte-identical to the pre-knob trace
    n_redundant_reads: int = 1
    # logit-sanity detection knobs of the paged decode step: finite but
    # |logit| above the saturation threshold evicts "saturated"; softmax
    # entropy strictly below the floor evicts "entropy_collapse" (0.0
    # disables the entropy check AND keeps the default trace unchanged)
    logit_sat_threshold: float = 1e6
    logit_entropy_floor: float = 0.0
    # graceful-degradation ladder; None disables the policy (detection
    # still evicts, but nothing downshifts)
    degradation: Optional[DegradationPolicy] = None

    def buckets(self) -> tuple[int, ...]:
        if not self.prefill_buckets:
            return tuple(_default_buckets(self.max_len))
        bs = tuple(sorted(set(self.prefill_buckets)))
        if any(b < 1 for b in bs):
            raise ValueError(f"prefill_buckets must be >= 1: {bs}")
        kept = tuple(b for b in bs if b <= self.max_len)
        if not kept:
            raise ValueError(
                f"every prefill bucket in {bs} exceeds max_len="
                f"{self.max_len}; no prompt could ever be admitted"
            )
        return kept

    def max_kv_blocks(self) -> int:
        """Block-table width: blocks covering one request's max_len."""
        return -(-self.max_len // self.kv_block_size)

    def pool_blocks(self, kv_cache_dtype: str = "same") -> int:
        """Total pool pages (incl. the reserved trash page 0).

        ``num_kv_blocks`` is a *memory budget* expressed in native-dtype
        blocks: an int8 pool's pages cost half the K/V bytes, so the same
        budget holds twice the pages (the trash page is counted once) —
        this is how quantization's capacity win reaches ``BlockAllocator``
        admission.  The default (0) is dense-parity capacity, already
        enough for every slot at full ``max_len``, so it is not doubled.
        """
        if self.num_kv_blocks:
            if kv_cache_dtype == "int8":
                return 2 * self.num_kv_blocks - 1
            return self.num_kv_blocks
        return self.max_batch * self.max_kv_blocks() + 1

    def validate(self, kv_cache_dtype: str = "same") -> None:
        """Loud, eager config validation (same spirit as :meth:`buckets`).

        Raises ValueError on an unknown ``kv_cache_dtype`` / ``kv_layout``,
        a non-positive ``kv_block_size``, or a ``num_kv_blocks`` too small
        to ever admit a single request — each of which would otherwise
        surface as an obscure failure deep inside admission or decode.
        """
        if kv_cache_dtype not in ("same", "int8"):
            raise ValueError(
                f"kv_cache_dtype must be 'same' or 'int8', got "
                f"{kv_cache_dtype!r}"
            )
        if self.kv_layout not in ("paged", "dense"):
            raise ValueError(
                f"kv_layout must be 'paged' or 'dense', got "
                f"{self.kv_layout!r}"
            )
        self.buckets()
        if self.kv_layout == "paged":
            if self.kv_block_size < 1:
                raise ValueError(
                    f"kv_block_size must be >= 1, got {self.kv_block_size}"
                )
            if not isinstance(self.enable_prefix_sharing, bool):
                # a truthy string like "off" would silently ENABLE sharing
                raise ValueError(
                    f"enable_prefix_sharing must be a bool, got "
                    f"{self.enable_prefix_sharing!r}"
                )
            if not isinstance(self.enable_preemption, bool):
                raise ValueError(
                    f"enable_preemption must be a bool, got "
                    f"{self.enable_preemption!r}"
                )
            if self.prefill_chunk < 0:
                raise ValueError(
                    f"prefill_chunk must be >= 0, got {self.prefill_chunk}"
                )
            if self.prefill_chunk and self.prefill_chunk % self.kv_block_size:
                # chunk boundaries must land on block boundaries: chunks
                # scatter whole blocks and the resume grid is block-indexed
                raise ValueError(
                    f"prefill_chunk={self.prefill_chunk} must be a "
                    f"multiple of kv_block_size={self.kv_block_size}"
                )
            # the smallest admissible request: shortest prefill bucket + one
            # generated token, whole lifetime reserved at admission
            need = -(
                -(min(self.buckets()) + 1) // self.kv_block_size
            )
            cap = self.pool_blocks(kv_cache_dtype) - 1  # minus trash page
            if cap < need:
                raise ValueError(
                    f"num_kv_blocks={self.num_kv_blocks} leaves a pool of "
                    f"{cap} allocatable blocks, but even the smallest "
                    f"request (bucket {min(self.buckets())} + 1 token) "
                    f"needs {need}; no request could ever be admitted"
                )
        elif self.prefill_chunk:
            raise ValueError(
                "prefill_chunk is a paged-layout knob; the dense layout "
                "prefills monolithically (it is the byte-identity oracle)"
            )
        if self.fault_injector is not None and self.kv_layout != "paged":
            raise ValueError(
                "fault_injector drives the paged allocator/pipeline; the "
                "dense layout is the fault-free byte-identity oracle"
            )
        if self.mesh is not None:
            if self.kv_layout != "paged":
                raise ValueError(
                    "mesh sharding is a paged-layout knob; the dense "
                    "layout is the single-device byte-identity oracle"
                )
            names = set(getattr(self.mesh, "axis_names", ()))
            if not {"data", "model"} <= names:
                raise ValueError(
                    f"serving mesh needs ('data', 'model') axes, got "
                    f"{sorted(names)}"
                )
        if self.speculate_k < 0:
            raise ValueError(
                f"speculate_k must be >= 0, got {self.speculate_k}"
            )
        if self.speculate_k:
            if self.kv_layout != "paged":
                raise ValueError(
                    "speculate_k > 0 drafts through the paged block pool; "
                    "the dense layout is the plain-decode byte-identity "
                    "oracle and cannot speculate"
                )
            if self.speculate_k >= self.max_new_tokens:
                # a draft run at least as long as the whole decode budget
                # can never amortize anything — it would overrun the
                # budget on round one and discard most of its work
                raise ValueError(
                    f"speculate_k={self.speculate_k} must be < the decode "
                    f"budget max_new_tokens={self.max_new_tokens}"
                )
        if self.device_backend not in BK.BACKENDS:
            raise ValueError(
                f"unknown device_backend {self.device_backend!r}; "
                f"registered: {sorted(BK.BACKENDS)}"
            )
        faulty = getattr(
            BK.BACKENDS[self.device_backend], "overrides_compute", False
        )
        if faulty and self.kv_layout != "paged":
            raise ValueError(
                f"device_backend={self.device_backend!r} overrides compute "
                "and needs the paged engine's rebuild/degradation loop; "
                "the dense layout is the healthy byte-identity oracle"
            )
        if self.device_fault_config is not None and not faulty:
            raise ValueError(
                "device_fault_config is only meaningful with a fault "
                f"backend (e.g. 'sim_faulty'); device_backend="
                f"{self.device_backend!r} would silently ignore it"
            )
        if self.n_redundant_reads < 1:
            raise ValueError(
                f"n_redundant_reads must be >= 1, got "
                f"{self.n_redundant_reads}"
            )
        if self.canary_interval < 0:
            raise ValueError(
                f"canary_interval must be >= 0, got {self.canary_interval}"
            )
        if self.canary_threshold <= 0.0:
            raise ValueError(
                f"canary_threshold must be > 0, got {self.canary_threshold}"
            )
        if not 0.0 <= self.tile_retire_threshold <= 1.0:
            raise ValueError(
                f"tile_retire_threshold must be in [0, 1], got "
                f"{self.tile_retire_threshold}"
            )
        if self.degradation is not None:
            pol = self.degradation
            if pol.trip_after < 1 or pol.recover_after < 1:
                raise ValueError(
                    "DegradationPolicy trip_after/recover_after must be "
                    f">= 1, got {pol.trip_after}/{pol.recover_after}"
                )
            if pol.redundant_reads < 1:
                raise ValueError(
                    "DegradationPolicy redundant_reads must be >= 1, got "
                    f"{pol.redundant_reads}"
                )
        if self.spill_budget_bytes is not None:
            if self.kv_layout != "paged":
                raise ValueError(
                    "spill_budget_bytes bounds the paged preemption spill "
                    "store; the dense layout never spills"
                )
            if self.spill_budget_bytes < 0:
                raise ValueError(
                    f"spill_budget_bytes must be >= 0, got "
                    f"{self.spill_budget_bytes}"
                )


@dataclasses.dataclass
class ServingMetrics:
    """Aggregate serving statistics (completed requests only)."""

    completed: int = 0
    total_tokens: int = 0
    wall_time: float = 0.0
    tokens_per_s: float = 0.0
    ttft_mean: float = 0.0      # submit → first generated token, seconds
    ttft_max: float = 0.0
    decode_steps: int = 0
    prefills: int = 0            # bucket prefills actually COMPUTED
    occupancy_mean: float = 0.0  # mean busy-slot fraction per decode step
    decode_time: float = 0.0     # seconds inside batched decode steps only
    prefix_hits: int = 0         # admissions that skipped prefill entirely
    cow_forks: int = 0           # shared blocks forked on first write
    prefix_partial_hits: int = 0  # admissions that mapped SOME prompt blocks
    prefill_tokens: int = 0       # prefill tokens actually computed
    prefill_tokens_saved: int = 0  # prompt tokens skipped via the index
    ttft_p50: float = 0.0         # TTFT percentiles over completed requests
    ttft_p99: float = 0.0
    preemptions: int = 0          # spill-to-host preemptions
    restores: int = 0             # spilled requests re-admitted
    spill_drops: int = 0          # spill records dropped by the bytes budget
    spec_rounds: int = 0          # fused draft+verify rounds dispatched
    spec_drafted: int = 0         # draft tokens considered by acceptance
    spec_accepted: int = 0        # drafted tokens accepted verbatim
    spec_acceptance: float = 0.0  # accepted / drafted
    spec_tokens_per_round: float = 0.0  # tokens emitted per verify call
    # done_reason -> count over every finished request ("eos"/"length" are
    # natural completions; "deadline"/"nan"/"preempted" are evictions)
    evictions: dict = dataclasses.field(default_factory=dict)
    # priority class -> {n, ttft_p50_ms, ttft_p99_ms, latency_p50_ms,
    # latency_p99_ms} — the per-class SLO view (latency = submit → done)
    latency_by_class: dict = dataclasses.field(default_factory=dict)
    # device-backend energy accounting snapshot: analog event tallies,
    # the per-token/per-sample/per-KV-token shape counts they reconcile
    # against, and Table I pricing under RACA vs 1-bit-ADC readout (see
    # DeviceBackend.snapshot).  Empty for the static reference engine.
    analog: dict = dataclasses.field(default_factory=dict)
    # ---- degraded-device serving ----
    degraded_mode: int = 0        # current DegradationPolicy rung (0..3)
    canary_probes: int = 0        # known-answer probes fired
    canary_failures: int = 0      # probes past canary_threshold
    retired_tiles: int = 0        # crossbar tiles remapped to spares
    redundant_read_events: int = 0  # extra comparator re-reads (priced)
    # every ladder transition: {tick, from, to, why} in firing order
    degraded_transitions: list = dataclasses.field(default_factory=list)

    @property
    def decode_step_ms(self) -> float:
        return self.decode_time * 1e3 / max(self.decode_steps, 1)

    def row(self) -> str:
        out = (
            f"tok_per_s={self.tokens_per_s:.1f} "
            f"ttft_ms={self.ttft_mean * 1e3:.1f} "
            f"ttft_p99_ms={self.ttft_p99 * 1e3:.1f} "
            f"step_ms={self.decode_step_ms:.2f} "
            f"occupancy={self.occupancy_mean:.2f}"
        )
        if self.preemptions or self.restores:
            out += f" preempt={self.preemptions} restore={self.restores}"
        if self.spill_drops:
            out += f" spill_drops={self.spill_drops}"
        if self.spec_rounds:
            out += (
                f" spec_acc={self.spec_acceptance:.2f} "
                f"spec_tok_per_round={self.spec_tokens_per_round:.1f}"
            )
        if self.evictions:
            out += " evict=" + ",".join(
                f"{k}:{v}" for k, v in sorted(self.evictions.items())
            )
        if self.degraded_mode or self.degraded_transitions:
            out += (
                f" degraded={self.degraded_mode}"
                f" transitions={len(self.degraded_transitions)}"
            )
        if self.canary_probes:
            out += (
                f" canary={self.canary_failures}/{self.canary_probes}"
            )
        if self.retired_tiles:
            out += f" retired_tiles={self.retired_tiles}"
        if self.redundant_read_events:
            out += f" redundant_reads={self.redundant_read_events}"
        if self.latency_by_class:
            out += " class=" + ",".join(
                f"{k}:n={v['n']}"
                f"/p99={v['latency_p99_ms']:.0f}ms"
                for k, v in sorted(self.latency_by_class.items())
            )
        if self.analog:
            out += (
                f" raca_pj_per_tok="
                f"{self.analog['raca']['energy_pj_per_token']:.0f}"
                f" adc1b_pj_per_tok="
                f"{self.analog['adc1b']['energy_pj_per_token']:.0f}"
            )
        return out


class ServingEngine:
    """Continuous-batching engine over a slot-addressable decode cache."""

    def __init__(self, params, model_cfg: ModelConfig, cfg: ServeConfig):
        if get_model_fns(model_cfg).prefill is None:
            raise ValueError(f"family {model_cfg.family!r} cannot decode")
        if model_cfg.family == "encdec":
            raise ValueError("encdec serving needs frames; token-LM only")
        # validate the whole serving config eagerly, not at admission
        cfg.validate(model_cfg.kv_cache_dtype)
        self.paged = cfg.kv_layout == "paged"
        self.int8 = self.paged and model_cfg.kv_cache_dtype == "int8"
        self.sharing = self.paged and cfg.enable_prefix_sharing
        self.mesh = cfg.mesh if self.paged else None
        self.spec_k = cfg.speculate_k if self.paged else 0
        self.params = params
        self.mcfg = model_cfg
        self.cfg = cfg
        self.sched = Scheduler(cfg.max_batch)
        b = cfg.max_batch
        # private per-engine device backend: analog-event accounting for
        # THIS engine's traffic only.  A compute-overriding backend
        # (sim_faulty) is additionally installed process-wide around each
        # tick (use_backend), so its faulty math reaches the traces; a
        # pure-accounting backend never touches the process dispatch.
        fault_kw = {}
        if cfg.device_fault_config is not None:
            fault_kw["fault"] = cfg.device_fault_config
        self.backend = BK.make_backend(
            cfg.device_backend, model_cfg, **fault_kw
        )
        # base WTA redundant-read factor (R=1 for greedy heads: a digital
        # argmax re-read can never change the vote)
        self._redundant_base = (
            max(int(cfg.n_redundant_reads), 1) if model_cfg.wta_head else 1
        )
        if self.paged:
            self._max_blocks = cfg.max_kv_blocks()
            self.blocks = BlockAllocator(
                cfg.pool_blocks(model_cfg.kv_cache_dtype), n_reserved=1
            )
            # host-authoritative block table; row = trash page 0 when free
            self._table = np.zeros((b, self._max_blocks), np.int32)
            # host mirror of cache["pos"] (drives the decode window width)
            self._host_pos = np.zeros((b,), np.int64)
            self._build_entry_points()
            # rid -> admission plan built by the gate (block hashes,
            # content-derived int8 quant seeds, resume depth, full-hit
            # flag); consumed by _admit_one.  A True gate always leads to
            # admission, so plans cannot leak.
            self._plans: dict[int, dict] = {}
            # rid -> (hashes, seeds): pure function of the prompt, but a
            # back-pressured queue head is re-gated every tick — memoize
            # so only the index lookups rerun per attempt
            self._hash_memo: dict[int, tuple] = {}
            # rid -> in-flight chunked-prefill job, processed FIFO (the
            # ordering that guarantees a sharer's source pages and
            # boundary-state payloads are resident before its first chunk)
            self._jobs: dict[int, dict] = {}
            self._job_fifo: list[int] = []
            # rid -> spill record of a preempted request (host np copies of
            # its pool pages + per-slot leaves + decode counters); consumed
            # by the restore branch of the gate / _admit_one.  Insertion
            # order doubles as the drop order under
            # ``cfg.spill_budget_bytes`` (oldest first — see _store_spill)
            self._spill: dict[int, dict] = {}
            self._spill_bytes = 0
            # recurrent/SSM families can only resume a partial-prefix hit
            # at a chunk boundary whose state snapshot is stashed;
            # attention-only families resume at any matched block
            self._stateful = any(
                k in ("rec", "ssm") for k in model_cfg.layer_pattern
            )
        else:
            self.blocks = None
            self._serve_step = jax.jit(
                SP.make_serve_step(model_cfg), donate_argnums=(1,)
            )
            self._insert = jax.jit(
                SP.make_cache_insert(model_cfg), donate_argnums=(0,)
            )
            self._prefill = jax.jit(self._make_prefill())
        self._base_key = jax.random.PRNGKey(cfg.seed)
        self._cache = None  # allocated lazily on first admission
        self._tokens = np.zeros((b,), np.int32)   # last emitted, per slot
        self._req_keys = np.zeros((b, 2), np.uint32)
        self._steps = np.zeros((b,), np.int32)    # tokens emitted, per slot
        self._injector = cfg.fault_injector if self.paged else None
        # rid -> already-published tokens a recompute-restored request must
        # teacher-force through decode instead of re-recording (set when a
        # spill record is dropped by the bytes budget; always empty for
        # the dense layout)
        self._replay: dict[int, list[int]] = {}
        self._ticks = 0
        self._preemptions = 0
        self._restores = 0
        self._spill_drops = 0
        self._spec_rounds = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._spec_emitted = 0
        self._occ_sum = 0.0
        self._decode_steps = 0
        self._prefills = 0
        self._prefix_hits = 0
        self._cow_forks = 0
        self._prefix_partial_hits = 0
        self._prefill_tokens = 0
        self._prefill_tokens_saved = 0
        self._total_tokens = 0
        self._busy_time = 0.0
        self._decode_time = 0.0
        # ---- degraded-device serving state ----
        self._degrade_level = 0
        self._dirty_streak = 0       # consecutive ticks with detections
        self._clean_streak = 0       # consecutive clean canary passes
        self._degraded_transitions: list[dict] = []
        self._canary_probes = 0
        self._canary_failures = 0
        self._tick_dirty = 0         # detection events in the current tick
        self._tick_canary: Optional[bool] = None
        self._canary_expected = (
            KOPS.canary_expected() if cfg.canary_interval else None
        )

    def _build_paged_serve_step(self, n_redundant: int):
        """Jit ONE paged serve-step variant at redundant-read factor R
        (mesh-aware when sharded).  Variants are cached per R — raising R
        under degradation compiles once per (R, window bucket) pair, and
        dropping back reuses the healthy artifact."""
        fn = SP.make_paged_serve_step(
            self.mcfg,
            n_redundant=n_redundant,
            sat_threshold=self.cfg.logit_sat_threshold,
            entropy_floor=self.cfg.logit_entropy_floor,
        )
        if self.mesh is not None:
            sh = self._shardings
            return jax.jit(
                fn,
                donate_argnums=(1,),
                in_shardings=(
                    sh["params"], sh["cache"], sh["table"],
                    sh["slot_vec"], sh["slot_keys"], sh["slot_vec"],
                ),
                out_shardings=(sh["cache"], sh["slot_vec"], sh["slot_vec"]),
            )
        return jax.jit(fn, donate_argnums=(1,))

    def _get_serve_step(self, n_redundant: int):
        fn = self._serve_steps.get(n_redundant)
        if fn is None:
            fn = self._build_paged_serve_step(n_redundant)
            self._serve_steps[n_redundant] = fn
        return fn

    def _build_entry_points(self) -> None:
        """(Re)build every paged jitted entry point.

        Called at construction, and again whenever the device backend's
        ``fault_version`` bumps: compiled artifacts keep the math they
        were TRACED with, so a drift-bucket crossing, tile retirement, or
        degrade/recover event leaves them computing yesterday's faults —
        the rebuild makes the next call retrace against the backend's
        current state.  Healthy backends never bump, so the recompile
        guards hold unchanged."""
        model_cfg, cfg, b = self.mcfg, self.cfg, self.cfg.max_batch
        base_r = self._redundant_base
        if self.mesh is not None:
            # sharded decode: the SAME four entry points, jitted with
            # mesh-aware in/out shardings (pool pages over data,
            # kv_heads over model, per-slot inputs over data; params
            # replicated).  Donation + static-arg discipline match
            # the unsharded jits, so the recompile guards hold.
            eps = SP.make_sharded_paged_entry_points(
                model_cfg, self.mesh, batch=b,
                n_pages=cfg.pool_blocks(model_cfg.kv_cache_dtype),
                block_size=cfg.kv_block_size,
                speculate_k=self.spec_k,
                n_redundant=base_r,
                sat_threshold=cfg.logit_sat_threshold,
                entropy_floor=cfg.logit_entropy_floor,
            )
            self._serve_step = eps["serve_step"]
            self._suffix_prefill = eps["suffix_prefill"]
            self._state_insert = eps["state_insert"]
            self._page_copy = eps["page_copy"]
            self._page_spill = eps["page_spill"]
            self._page_restore = eps["page_restore"]
            self._state_gather = eps["state_gather"]
            if self.spec_k:
                self._spec_round = eps["spec_round"]
                self._spec_rollback = eps["spec_rollback"]
            self._shardings = eps["shardings"]
            # params live replicated on the mesh — placed ONCE here (a
            # rebuild re-put of already-placed params is a no-op), not
            # re-transferred per call
            self.params = jax.device_put(
                self.params, self._shardings["params"]
            )
        else:
            self._serve_step = self._build_paged_serve_step(base_r)
            # THE paged prefill: a resumable suffix-chunk step (cold
            # prefills run their whole bucket as chunks from zeroed
            # state, partial-prefix hits start at q0 > 0 attending
            # into shared pages).  ``bucket`` is the only static
            # argument — one compile per (bucket, chunk shape) pair;
            # the cache is donated (in-place page writes), the
            # threaded state is NOT (boundary snapshots are stashed
            # in the prefix index and must survive the next chunk
            # call).
            self._suffix_prefill = jax.jit(
                SP.make_paged_suffix_prefill(model_cfg),
                static_argnames=("bucket",), donate_argnums=(1,),
            )
            # prefix-sharing entry points (each compiles at most once
            # — state-leaf shapes are bucket-independent, page ids /
            # logits shapes are fixed): completion/full-hit
            # admissions insert per-slot state leaves, sample the
            # first token from last chunk (or stored) logits, and
            # COW forks copy one pool page onto another
            self._state_insert = jax.jit(
                SP.make_paged_state_insert(model_cfg),
                donate_argnums=(0,),
            )
            self._page_copy = jax.jit(
                SP.make_page_copy(model_cfg), donate_argnums=(0,)
            )
            # preemption entry points (one compile each: page ids ride
            # at the FIXED table width, padded with the trash page):
            # spill gathers a victim's pages for the host-side store
            # (no donation — the cache stays live for the survivors),
            # restore scatters them back at re-admission, and the
            # slot-state gather reads the victim's dense per-slot
            # leaves (pos + recurrent/SSM states)
            self._page_spill = jax.jit(SP.make_page_spill(model_cfg))
            self._page_restore = jax.jit(
                SP.make_page_restore(model_cfg), donate_argnums=(0,)
            )
            self._state_gather = jax.jit(
                SP.make_slot_state_gather(model_cfg)
            )
            if self.spec_k:
                # speculative entry points: the fused draft+verify
                # round (one compile per (window, k) pair — same
                # power-of-two window bucketing as serve_step) and
                # the single-slot rollback (idx + slot traced, ONE
                # compile for the engine's lifetime)
                self._spec_round = jax.jit(
                    SP.make_paged_spec_round(model_cfg, self.spec_k),
                    donate_argnums=(1,),
                )
                self._spec_rollback = jax.jit(
                    SP.make_spec_rollback(model_cfg),
                    donate_argnums=(0,),
                )
        # serve-step variants keyed by redundant-read factor R; the base
        # variant serves healthy traffic, level-2 degradation adds its own
        self._serve_steps = {base_r: self._serve_step}
        self._sample0 = jax.jit(
            lambda logits, key: SP.sample_tokens(
                model_cfg, logits, key[None, :],
                jnp.zeros((1,), jnp.int32),
            )
        )
        # known-answer canary probe through the ACTIVE backend; rebuilt
        # with the rest so it always measures the current fault state.
        # Jitted via a fresh closure: jit's trace cache is keyed on the
        # function object, so jitting the module-level canary_mac
        # directly would keep serving the pre-rebuild trace forever.
        self._canary = jax.jit(lambda key: KOPS.canary_mac(key))
        self._fault_version_seen = getattr(
            self.backend, "fault_version", 0
        )

    def _check_fault_version(self) -> None:
        """Rebuild stale jitted entry points after a backend fault-state
        change (drift bucket, retirement, degrade/recover)."""
        v = getattr(self.backend, "fault_version", None)
        if v is not None and v != self._fault_version_seen:
            self._build_entry_points()

    def _make_prefill(self):
        """Monolithic one-request prefill — the DENSE layout only (the
        paged layout's prefill is the chunked ``_suffix_prefill``, which
        subsumes it; a single whole-bucket chunk is bit-identical)."""
        cfg, max_len = self.mcfg, self.cfg.max_len

        def prefill(params, tokens, key):  # tokens (1, L), key (2,) uint32
            fns = get_model_fns(cfg)
            cache, logits = fns.prefill(
                params, {"tokens": tokens}, cfg, max_len
            )
            tok0 = SP.sample_tokens(
                cfg, logits, key[None, :], jnp.zeros((1,), jnp.int32)
            )
            return cache, tok0, logits

        return prefill

    # -- request API --------------------------------------------------------

    def submit(
        self,
        prompt_tokens: Sequence[int],
        max_new_tokens: Optional[int] = None,
        priority: int = 1,
        deadline_ms: Optional[float] = None,
    ) -> int:
        """Queue a request; returns its request id.

        ``priority`` is the scheduling class (lower = more urgent;
        ``PRIORITY_INTERACTIVE=0`` overtakes ``PRIORITY_BATCH=1`` at
        admission and may preempt it under pool pressure).  ``deadline_ms``
        is a completion SLO from now: the engine's deadline pass evicts
        the request with reason ``"deadline"`` once it expires, whatever
        state it is in."""
        n = len(prompt_tokens)
        if n == 0:
            # an empty prompt would left-pad to an all-pad window and seed
            # decoding from the logits of a pad token — refuse loudly
            # (same spirit as the max_len check below)
            raise ValueError(
                "empty prompt: at least one prompt token is required "
                "(decoding seeds from the last prompt token's logits)"
            )
        if n > max(self.cfg.buckets()):
            raise ValueError(
                f"prompt length {n} exceeds largest prefill bucket "
                f"{max(self.cfg.buckets())}"
            )
        budget = (
            self.cfg.max_new_tokens if max_new_tokens is None
            else max_new_tokens
        )
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        need = self._bucket(n) + budget
        if need > self.cfg.max_len:
            # decode would write past cache capacity (the dynamic-slice
            # write clamps and silently corrupts the last position)
            raise ValueError(
                f"prefill bucket {self._bucket(n)} + {budget} new tokens "
                f"= {need} exceeds cache max_len={self.cfg.max_len}"
            )
        if self.paged:
            nb = self._blocks_needed(self._bucket(n), budget)
            if nb > self.blocks.capacity:
                raise ValueError(
                    f"request needs {nb} KV blocks but the pool only has "
                    f"{self.blocks.capacity}; raise num_kv_blocks"
                )
        req = self.sched.submit(
            prompt_tokens, budget, now=time.perf_counter(),
            priority=priority, deadline_ms=deadline_ms,
        )
        return req.rid

    def _bucket(self, n: int) -> int:
        return next(b for b in self.cfg.buckets() if b >= n)

    def _blocks_needed(self, bucket: int, budget: int) -> int:
        """Whole-lifetime block budget: prefill window + decode tokens.

        Allocated up-front at admission so a decoding request can never hit
        pool exhaustion mid-flight (the paged analogue of the dense
        engine's max_len check in :meth:`submit`)."""
        return -(-(bucket + budget) // self.cfg.kv_block_size)

    def _init_cache(self):
        if self.paged:
            cache = SP.init_paged_decode_cache(
                self.mcfg, self.cfg.max_batch,
                self.cfg.pool_blocks(self.mcfg.kv_cache_dtype),
                self.cfg.kv_block_size,
            )
            if self.mesh is not None:
                # place the pool sharded from the start: pages over data,
                # kv_heads over model — each device holds 1/|data| of the
                # pool, which is where capacity scaling comes from
                cache = jax.device_put(cache, self._shardings["cache"])
            return cache
        return SP.init_decode_cache(
            self.mcfg, self.cfg.max_batch, self.cfg.max_len
        )

    def _put(self, x, kind: str):
        """Host→device transfer for a per-tick decode input.

        Unsharded engines take the plain ``jnp.asarray`` path; under a
        mesh the transfer is PLACED (``jax.device_put`` with the entry
        point's NamedSharding) so the jit never needs a follow-up
        reshard of an uncommitted array."""
        if self.mesh is None:
            return jnp.asarray(x)
        return jax.device_put(np.asarray(x), self._shardings[kind])

    def _put_tree(self, tree, kind: str):
        """Like :meth:`_put` for a pytree (spill payloads, state leaves)."""
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, tree)
        return jax.device_put(tree, self._shardings[kind])

    def _chunk_tokens(self, bucket: int) -> int:
        """The prefill chunk grid for ``bucket`` (0 → whole bucket)."""
        return min(self.cfg.prefill_chunk or bucket, bucket)

    def _resume_tokens(self, n_matched: int, bucket: int) -> int:
        """How many prompt tokens a partial hit can SKIP computing.

        Attention-only families resume at the full matched depth: suffix
        hidden states are per-position functions of (token, attended
        K/V), so any block boundary is an exact resume point.
        Recurrent/SSM families additionally need the carried state at the
        resume point, which the chunked prefill stashes at CHUNK
        boundaries only — so the matched depth truncates down to the
        chunk grid (every registrant shares the grid, so a grid-boundary
        block always carries a state snapshot by the time this request's
        first chunk runs — FIFO job order).
        """
        p = n_matched * self.cfg.kv_block_size
        if not self._stateful:
            return p
        grid = self._chunk_tokens(bucket)
        return (p // grid) * grid

    def _try_reserve_blocks(self, req: Request) -> bool:
        """Admission gate: reserve the request's whole block budget, or
        refuse.  Reserving *inside* the gate (not later in the prefill) is
        what makes multi-admission ticks safe: each True answer has already
        taken its pages, so the next queue head is gated against what is
        actually left.  A True from the gate always leads to admission and
        a False leaves the allocator COMPLETELY untouched — matching is a
        read-only probe (``longest_prefix_match``) and the refcount bumps
        for the mapped pages happen only inside the atomic ``reserve``, so
        a refused or re-gated request can never leak a reference
        (tests/test_serving.py::test_admission_gate_refusal_has_no_side_effects).

        With prefix sharing the gate maps the deepest resident chain hit
        into the request's table (refcount bump — capacity win even when
        the compute resume point truncates below it), reserves one spare
        COW page for a full hit ending in a partial boundary block (the
        request WILL write there at its first decode token), and registers
        the request's own fresh prompt blocks immediately so same-tick
        duplicates already share; their CONTENT lands later, chunk by
        chunk, which is safe because prefill jobs run FIFO — a sharer's
        first chunk never precedes its source's covering chunk.
        """
        bucket = self._bucket(len(req.prompt))
        nb_total = self._blocks_needed(bucket, req.max_new_tokens)
        bs = self.cfg.kv_block_size
        n_prompt = -(-bucket // bs)
        plan: dict = {
            "full_hit": False, "hashes": None, "seeds": None,
            "n_prompt": n_prompt, "n_shared": 0, "resume": 0,
            "bucket": bucket,
        }
        if self.sharing or self.int8:
            memo = self._hash_memo.get(req.rid)
            if memo is None:
                hashes = prefix_block_hashes(
                    left_pad(req.prompt, bucket), bs
                )
                # canonical int8 rounding seeds: content-derived per
                # block, so identical prefixes re-quantize to
                # bit-identical codes
                memo = (
                    hashes,
                    np.asarray([s for _, s in hashes], np.uint32),
                )
                self._hash_memo[req.rid] = memo
            plan["hashes"], plan["seeds"] = memo
        rec = self._spill.get(req.rid)
        if rec is not None:
            return self._gate_restore(req, plan, rec, nb_total)
        shared: list[int] = []
        if self.sharing:
            shared = self.blocks.longest_prefix_match(
                [h for h, _ in plan["hashes"]]
            )
        full = len(shared) == n_prompt
        # a shared partial boundary block is written at the first decode
        # token — reserve its fork page NOW so the COW can never starve
        n_spare = 1 if (full and bucket % bs != 0) else 0
        n_new = nb_total - len(shared)
        if not self.blocks.can_alloc(n_new + n_spare):
            return False
        pages = self.blocks.reserve(req.rid, n_new, shared, n_spare)
        if self.sharing:
            for i in range(len(shared), n_prompt):
                self.blocks.register(pages[i], plan["hashes"][i][0])
            plan["full_hit"] = full
            plan["n_shared"] = len(shared)
            if not full:
                plan["resume"] = self._resume_tokens(len(shared), bucket)
        self._plans[req.rid] = plan
        return True

    def _gate_restore(
        self, req: Request, plan: dict, rec: dict, nb_total: int
    ) -> bool:
        """Admission gate for a preempted (spilled) request.

        Same atomic shape as the fresh-admission gate, with two twists.
        First, the prefix probe is truncated to the request's PRISTINE
        prompt blocks: once a decode step has written into an unaligned
        boundary block (``rec["dirty"]``), that block's content diverged
        from its chain hash — taking a pristine index hit there would
        silently drop the decoded rows, so the spilled copy must come back
        instead.  Second, fresh pristine prompt blocks re-register under
        their hashes (guarded: an identical prompt may have re-registered
        them while this request sat spilled), so a restored request is a
        first-class sharing citizen again.
        """
        bucket, bs = plan["bucket"], self.cfg.kv_block_size
        n_prompt = plan["n_prompt"]
        n_clean = n_prompt - 1 if rec["dirty"] else n_prompt
        shared: list[int] = []
        if self.sharing:
            shared = self.blocks.longest_prefix_match(
                [h for h, _ in plan["hashes"]][:n_clean]
            )
        # an undirtied full match of an unaligned prompt WILL write its
        # shared boundary block at the first decode step — same COW spare
        # rule as a fresh full-hit admission
        n_spare = 1 if (
            len(shared) == n_prompt and bucket % bs != 0
        ) else 0
        n_new = nb_total - len(shared)
        if not self.blocks.can_alloc(n_new + n_spare):
            return False
        pages = self.blocks.reserve(req.rid, n_new, shared, n_spare)
        if self.sharing:
            for i in range(len(shared), n_clean):
                if self.blocks.lookup(plan["hashes"][i][0]) is None:
                    self.blocks.register(pages[i], plan["hashes"][i][0])
            plan["n_shared"] = len(shared)
        plan["restore"] = True
        self._plans[req.rid] = plan
        return True

    def _release_if_done(self, req: Request) -> None:
        """Reclaim an evicted request's KV blocks and neutralize its slot.

        The request's page references (mapped + any unspent COW spare) are
        released; pages reach the free list only at refcount zero — a
        prefix block still shared by another live request survives, and
        its index entry with it.  The slot's table row is pointed at the
        trash page so the still-running batched decode step writes nowhere
        a live request reads — this is how a mid-flight refill recycles
        memory."""
        if not (self.paged and req.state is RequestState.DONE):
            return
        self.blocks.free(req.rid)
        # eviction nulled req.slot (the slot is no longer this request's
        # — the next admission reuses it); the historical binding lives
        # in req.done_slot, which is the row to neutralize here
        self._table[req.done_slot, :] = 0

    def _admit_one(self, req: Request) -> None:
        """Bind an admitted request to its slot.

        Dense: monolithic prefill + slot insert, decode starts immediately
        (the PR-1 oracle path, unchanged).  Paged: enqueue a chunked
        prefill job — the slot's table row stays pointed at the trash page
        and its per-slot cache leaves stay engine-owned (threaded through
        the chunk steps host-side) until the job completes, so the batched
        decode steps running for the OTHER slots in the meantime can never
        corrupt a prefill in flight."""
        slot = req.slot
        plen = self._bucket(len(req.prompt))
        rkey = jax.random.fold_in(self._base_key, req.rid)
        if self._cache is None:
            self._cache = self._init_cache()
        self._req_keys[slot] = np.asarray(rkey)
        if not self.paged:
            toks = np.asarray([left_pad(req.prompt, plen)], np.int32)
            one_cache, tok0, _ = self._prefill(
                self.params, jnp.asarray(toks), rkey
            )
            self._cache = self._insert(self._cache, one_cache, slot)
            self._prefills += 1
            self._prefill_tokens += plen
            # monolithic dense prefill forwards the whole padded bucket
            # and samples the first token in the same call
            self.backend.note_call(
                SP.analog_call_profile("suffix_prefill", tokens=plen)
            )
            self.backend.note_call(SP.analog_call_profile("sample0"))
            self._finish_admission(req, tok0)
            return
        plan = self._plans.pop(req.rid)
        self._hash_memo.pop(req.rid, None)
        if plan.get("restore"):
            self._restore_one(req, plan)
            return
        pages = self.blocks.owned(req.rid)  # reserved by the gate
        row = np.zeros((self._max_blocks,), np.int32)
        row[: len(pages)] = pages
        if plan["full_hit"]:
            # stash the terminal payload NOW if it already exists: the
            # registrant may in-place-diverge its partial boundary block
            # (dropping the index entry and payload with it) before this
            # job reaches the head of the prefill FIFO.  A logits-less
            # (None, state) payload is a CHUNK-BOUNDARY snapshot of a
            # longer in-flight prompt whose grid boundary happens to be
            # this prompt's terminal hash — not a terminal payload; the
            # job will demote to a suffix recompute instead.
            payload = self.blocks.payload(plan["hashes"][-1][0])
            plan["payload"] = (
                payload
                if payload is not None and payload[0] is not None
                else None
            )
        elif plan["n_shared"] > 0:
            self._prefix_partial_hits += 1
            self._prefill_tokens_saved += plan["resume"]
        self._jobs[req.rid] = {
            "req": req,
            "row": row,
            "plan": plan,
            "q0": plen if plan["full_hit"] else plan["resume"],
            "bucket": plen,
            "rkey": rkey,
            "state": None,
            "tokens": left_pad(req.prompt, plen),
        }
        self._job_fifo.append(req.rid)

    def _restore_one(self, req: Request, plan: dict) -> None:
        """Re-bind a spilled request to its new slot, byte-exactly.

        Shared prefix pages came back as index hits through the gate; the
        rest of the request's USED pages (suffix prompt blocks, the dirty
        boundary block, decoded tail blocks) scatter back from the spilled
        payload — positions the request never reached point at the trash
        page, so one fixed-width restore compile serves every shape.  The
        per-slot leaves (``pos`` + recurrent/SSM state), last token, step
        counter, and per-request PRNG key are restored verbatim, which is
        what makes the remaining token stream byte-identical to an
        un-preempted run (the key is ``fold_in(base, rid)`` — a pure
        function of the rid — and WTA noise is a function of (key, step)).
        No token is recorded here: the request resumes mid-stream.
        """
        rec = self._pop_spill(req.rid)
        slot = req.slot
        pages = self.blocks.owned(req.rid)
        row = np.zeros((self._max_blocks,), np.int32)
        row[: len(pages)] = pages
        ids = np.zeros((self._max_blocks,), np.int32)
        n_shared = plan["n_shared"]
        ids[n_shared : rec["n_used"]] = row[n_shared : rec["n_used"]]
        self._cache = self._page_restore(
            self._cache,
            self._put(ids, "replicated"),
            self._put_tree(rec["pages"], "replicated"),
        )
        self._cache = self._state_insert(
            self._cache,
            self._put_tree(rec["state"], "replicated"),
            slot,
        )
        self._table[slot] = row
        self._host_pos[slot] = rec["pos"]
        self._tokens[slot] = rec["token"]
        self._steps[slot] = rec["steps"]
        self.sched.start_decode(req)
        self._restores += 1

    def _finish_admission(self, req: Request, tok0) -> None:
        """Shared admission tail: first token, decode start, bookkeeping."""
        slot = req.slot
        self.sched.start_decode(req)
        rep = self._replay.get(req.rid)
        if rep:
            # recompute-restore of a dropped spill record: the first
            # `len(rep)` tokens were already published before the
            # preemption — seed decode with the recorded first token
            # (bitwise what `tok0` just resampled) and teacher-force the
            # rest through the ordinary ticks; nothing re-records
            self._tokens[slot] = rep.pop(0)
            if not rep:
                del self._replay[req.rid]
            self._steps[slot] = 1
            return
        t0 = int(tok0[0])  # blocks on the prefill — TTFT stamps after it
        self._tokens[slot] = t0
        self._steps[slot] = 1
        self._total_tokens += 1
        self.sched.record_token(
            req, t0, self.cfg.eos_token, time.perf_counter()
        )
        self._release_if_done(req)  # budget=1 or instant EOS

    def _complete_job(self, rid: int, job: dict, tok0) -> None:
        """Finish a chunked-prefill job: publish the real block-table row
        (decode writes may now land in the request's own pages), mirror
        the final position, and start decoding."""
        req = job["req"]
        self._table[req.slot] = job["row"]
        self._host_pos[req.slot] = job["bucket"]
        self._job_fifo.pop(0)
        del self._jobs[rid]
        self._finish_admission(req, tok0)

    def _resume_state(self, plan: dict, q0: int) -> dict:
        """State leaves entering a job's first computed chunk: zeroed for
        a cold start, the stashed boundary snapshot for a stateful
        partial-prefix resume (attention-only families carry no recurrent
        state — their resume needs only the shared pages)."""
        if q0 == 0 or not self._stateful:
            return SP.init_prefill_state(self.mcfg)
        h = plan["hashes"][q0 // self.cfg.kv_block_size - 1][0]
        payload = self.blocks.payload(h)
        assert payload is not None, (
            "missing boundary-state snapshot for a grid-aligned resume"
        )
        return payload[1]

    # -- preemption / eviction ----------------------------------------------

    @staticmethod
    def _spill_nbytes(rec: dict) -> int:
        """Host bytes a spill record pins: its page payload + state leaves
        (the np arrays — counters and ints are noise).  The bytes-based KV
        cost framing: a record's weight is what its K/V actually costs at
        the pool dtype, so int8 records charge half the budget of bf16
        ones for the same token count."""
        return int(sum(
            leaf.nbytes
            for leaf in jax.tree.leaves((rec["pages"], rec["state"]))
        ))

    def _pop_spill(self, rid: int) -> Optional[dict]:
        """Remove a spill record (restore / cancel), keeping the bytes
        accounting exact.  Returns the record, or None if it was never
        stored — or already dropped by the budget."""
        rec = self._spill.pop(rid, None)
        if rec is not None:
            self._spill_bytes -= self._spill_nbytes(rec)
        return rec

    def _store_spill(self, rid: int, rec: dict) -> None:
        """Insert a spill record, then enforce ``spill_budget_bytes``.

        Over the cap, the OLDEST records drop first (dict insertion order
        — a record is only ever touched again when popped for restore, so
        age IS recency).  The just-inserted record is eligible too: a
        single record larger than the whole budget drops immediately.  A
        dropped record's request stays queued and re-admits through the
        normal fresh gate — full grid-aligned prompt recompute through
        the chunked prefill (prefix hits still apply) — and its
        already-published tokens move to ``_replay``: decode teacher-
        forces them back, re-deriving the decoded tail's K/V bit-for-bit
        without re-publishing anything (greedy/WTA sampling is a pure
        function of (key, step), so the recomputed tokens ARE the
        published ones).
        """
        self._spill[rid] = rec
        self._spill_bytes += self._spill_nbytes(rec)
        budget = self.cfg.spill_budget_bytes
        if budget is None:
            return
        while self._spill and self._spill_bytes > budget:
            old_rid = next(iter(self._spill))
            old = self._pop_spill(old_rid)
            self._replay[old_rid] = list(old["replay"])
            self._spill_drops += 1

    def _preempt(self, req: Request) -> None:
        """Spill a DECODING request to the host-side store and requeue it.

        The victim's USED pages (``ceil(pos / block_size)`` of them, padded
        with the trash page to the fixed table width — one spill compile
        ever) gather to host memory together with its per-slot leaves and
        decode counters; then its whole reservation is released — shared
        prefix pages survive for their other owners, private pages hit the
        free list immediately, which is the capacity the preempting
        request is about to take.  The scheduler requeues the victim at
        the head of its priority class.
        """
        slot, rid = req.slot, req.rid
        pages = self.blocks.owned(rid)
        pos = int(self._host_pos[slot])
        bs = self.cfg.kv_block_size
        bucket = self._bucket(len(req.prompt))
        n_used = -(-pos // bs)
        ids = np.zeros((self._max_blocks,), np.int32)
        ids[:n_used] = pages[:n_used]
        payload = jax.tree.map(
            np.asarray,
            self._page_spill(self._cache, self._put(ids, "replicated")),
        )
        state = jax.tree.map(
            np.asarray,
            self._state_gather(self._cache, slot),
        )
        self._store_spill(rid, {
            "bucket": bucket,
            "n_used": n_used,
            "pos": pos,
            # once a decode step wrote into an unaligned boundary prompt
            # block, its content diverged from the chain hash — the
            # restore gate must NOT take a pristine index hit there
            "dirty": bucket % bs != 0 and pos > bucket,
            "pages": payload,
            "state": state,
            "token": int(self._tokens[slot]),
            "steps": int(self._steps[slot]),
            # published so far — the replay list if this record is later
            # dropped by the bytes budget (frozen: a queued request
            # publishes nothing until it decodes again)
            "replay": list(req.output),
        })
        self.blocks.free(rid)
        self._table[slot, :] = 0
        self.sched.requeue(req)
        self._preemptions += 1

    def _preempt_pass(self) -> None:
        """Evict lowest-priority decoders until the queue head admits.

        Runs after normal admission: while the most-urgent queued request
        outranks some DECODING request (strictly — uniform-priority
        traffic never preempts), spill the weakest victim (lowest class,
        then newest) and retry admission.  Each round shrinks the active
        set by one, so the loop is bounded by ``max_batch``; it stops as
        soon as the head stops outranking the floor — either because it
        was admitted or because only its own class (or better) remains
        live.
        """
        while True:
            head = self.sched.peek()
            if head is None:
                return
            victims = [
                r for r in self.sched.active()
                if r.priority > head.priority
            ]
            if not victims:
                return
            victim = max(victims, key=lambda r: (r.priority, r.rid))
            self._preempt(victim)
            for req in self.sched.admit(self._try_reserve_blocks):
                self._admit_one(req)

    def _evict_request(self, req: Request, reason: str, now: float) -> None:
        """Terminally evict a request in ANY live state, atomically.

        QUEUED requests cancel off the queue (dropping any spill record —
        an expired preempted request never comes back); PREFILL requests
        drop their pipeline job and free every reserved page
        (:meth:`_kill_job`); DECODING requests release through the normal
        eviction path.  Every path stamps the typed ``done_reason``.

        Logit-sanity evictions also count as detection events for the
        degradation policy's per-tick pressure signal.
        """
        if reason in SP.SANITY_REASONS.values():
            self._tick_dirty += 1
        if req.state is RequestState.QUEUED:
            self.sched.cancel(req, reason, now)
            if self.paged:
                self._hash_memo.pop(req.rid, None)
                self._pop_spill(req.rid)
                self._replay.pop(req.rid, None)
        elif req.state is RequestState.PREFILL:
            if self.paged:
                self._kill_job(req)
            self.sched.evict(req, reason, now)
            if self.paged:
                self._table[req.done_slot, :] = 0
        elif req.state is RequestState.DECODE:
            self.sched.evict(req, reason, now)
            self._release_if_done(req)

    def _kill_job(self, req: Request) -> None:
        """Drop an in-flight chunked-prefill job and free its pages.

        The dead job's registered-but-not-fully-written prompt blocks are
        deregistered BEFORE the free (their content never finished
        landing; leaving them indexed would hand garbage to later
        admissions).  Any such page still alive through a sharer's
        reservation is *garbage with a believer*: jobs queued behind this
        one mapped it at their gate assuming FIFO order would fill it —
        each is demoted to recompute from before its first garbage block
        (:meth:`_demote_job_for_garbage`).  Jobs AHEAD in the FIFO cannot
        reference these pages (they were registered at this job's gate,
        after theirs), and no DECODING request can either (completion is
        FIFO too), so the cascade over queued jobs is exhaustive.
        """
        rid = req.rid
        job = self._jobs.pop(rid)
        self._job_fifo.remove(rid)
        plan = job["plan"]
        garbage: set[int] = set()
        if self.sharing:
            bs = self.cfg.kv_block_size
            for i in range(plan["n_shared"], plan["n_prompt"]):
                if job["q0"] < min((i + 1) * bs, job["bucket"]):
                    page = int(job["row"][i])
                    self.blocks.deregister(page)
                    garbage.add(page)
        self.blocks.free(rid)
        garbage = {p for p in garbage if self.blocks.refcount(p) > 0}
        for orid in self._job_fifo:
            self._demote_job_for_garbage(self._jobs[orid], garbage)

    def _demote_job_for_garbage(self, job: dict, garbage: set) -> None:
        """Lower a queued job's resume point below its first garbage page.

        ``garbage`` pages are mapped in ``job["row"]`` but their promised
        content died with the killed writer.  Everything BELOW the first
        garbage block is still valid (written, or registered by a live
        owner); the job recomputes from there — rewriting the garbage
        pages itself, with exactly the bits the dead writer would have
        produced (content-derived int8 seeds keep even quantized blocks
        bit-identical across writers).  Only the FIFO head ever advances
        ``q0``, so a demoted job has not computed anything yet and its
        threaded state is still unset; stateful families additionally
        walk down the chunk grid to the deepest boundary whose state
        snapshot is still stashed.
        """
        if not garbage:
            return
        plan = job["plan"]
        bs = self.cfg.kv_block_size
        frontier = min(-(-job["q0"] // bs), plan["n_prompt"])
        bad = next(
            (
                i for i in range(frontier)
                if int(job["row"][i]) in garbage
            ),
            None,
        )
        if bad is None:
            return
        q0 = bad * bs
        if self._stateful:
            grid = self._chunk_tokens(job["bucket"])
            q0 = (q0 // grid) * grid
            while q0 > 0 and self.blocks.payload(
                plan["hashes"][q0 // bs - 1][0]
            ) is None:
                q0 -= grid
        plan["full_hit"] = False
        job["q0"] = q0
        job["state"] = None

    def _nan_payload(self) -> dict:
        """A cached all-non-finite page payload for the NaN injector.

        Float pool leaves (K/V or their scale planes) get a NaN row at
        payload index 0 ONLY — an int8 pool's dequant is ``code * NaN
        scale``, so the poison propagates at any pool dtype.  The other
        rows stay zero: the scatter's fixed-width ids pad with the trash
        page, and NaN-ing the trash page would non-finite EVERY slot
        (masked attention weights are exactly 0, but 0·NaN = NaN on the
        V side).  Shapes match the spill payload, so scattering reuses
        the one restore compile.
        """
        if getattr(self, "_nan_rows", None) is None:
            self._nan_rows = {}
            for name in SP.PAGE_POOL_LEAVES:
                if name in self._cache:
                    leaf = self._cache[name]
                    shape = list(leaf.shape)
                    shape[2] = self._max_blocks
                    dt = np.dtype(leaf.dtype)
                    rows = np.zeros(shape, dt)
                    # jnp.issubdtype, not np: bfloat16 is an ml_dtypes
                    # extension type that numpy does not class as floating
                    if jnp.issubdtype(dt, jnp.floating):
                        rows[:, :, 0] = np.nan
                    self._nan_rows[name] = rows
        return self._nan_rows

    def _poison_nan(self, req: Request) -> bool:
        """Overwrite one of ``req``'s PRIVATE read-window pages with NaNs.

        The injected analog-garbage fault: the next decode step reads the
        poisoned block, its logits go non-finite, and the engine's ok-flag
        guard evicts the request with reason ``"nan"``.  Only a
        refcount-1 page may be poisoned (corrupting a shared page would
        take innocent requests down with it); the page is deregistered
        first, exactly as a real content divergence would be.  Returns
        False if the request has no private page in its read window yet
        (a fresh full-hit admission) — the injector then tries another
        victim.
        """
        slot, rid = req.slot, req.rid
        pages = self.blocks.owned(rid)
        n_read = max(1, -(-int(self._host_pos[slot]) // self.cfg.kv_block_size))
        target = next(
            (
                i for i in reversed(range(min(n_read, len(pages))))
                if self.blocks.refcount(pages[i]) == 1
            ),
            None,
        )
        if target is None:
            return False
        self.blocks.deregister(pages[target])
        # payload row 0 is the NaN row (see _nan_payload); the rest of the
        # fixed-width vector scatters harmless zeros into the trash page
        ids = np.zeros((self._max_blocks,), np.int32)
        ids[0] = pages[target]
        self._cache = self._page_restore(
            self._cache,
            self._put(ids, "replicated"),
            self._put_tree(self._nan_payload(), "replicated"),
        )
        return True

    def _prefill_tick(self, emitted: list[tuple[int, int]]) -> None:
        """Advance the chunked-prefill pipeline by at most one compute
        chunk (≤ ``prefill_chunk`` tokens), completing any number of
        zero-compute full hits along the way.

        Jobs run strictly FIFO — the ordering that makes gate-time
        registration safe: by the time a sharer's first chunk (or a full
        hit's payload fetch) runs, the source request's covering chunks
        have already written their pages and boundary snapshots."""
        computed = False
        while self._job_fifo:
            rid = self._job_fifo[0]
            job = self._jobs[rid]
            req, plan = job["req"], job["plan"]
            bucket = job["bucket"]
            if plan["full_hit"]:
                payload = plan.get("payload") or self.blocks.payload(
                    plan["hashes"][-1][0]
                )
                # a logits-less boundary snapshot cannot seed the first
                # token — only a completed identical prompt's terminal
                # (logits, state) can; anything else demotes below (the
                # recompute republishes terminal logits on the hash, so
                # LATER repeats of this prompt full-hit properly)
                if payload is not None and payload[0] is not None:
                    logits, state = payload
                    self._cache = self._state_insert(
                        self._cache, state, req.slot
                    )
                    tok0 = self._sample0(logits, job["rkey"])
                    self.backend.note_call(
                        SP.analog_call_profile("sample0")
                    )
                    self._prefix_hits += 1
                    self._prefill_tokens_saved += bucket
                    self._complete_job(rid, job, tok0)
                    emitted.append((rid, req.output[-1]))
                    continue
                # no usable terminal payload: it died while this job
                # waited (the registrant in-place-diverged its boundary
                # block with its decode writes), or the matched terminal
                # hash only ever carried a longer prompt's chunk-boundary
                # snapshot.  Demote to a minimal grid-aligned suffix
                # recompute — the interior shared pages are still
                # content-valid, only the boundary block and the
                # (logits, state) must be regenerated
                plan["full_hit"] = False
                grid = (
                    self._chunk_tokens(bucket) if self._stateful
                    else self.cfg.kv_block_size
                )
                job["q0"] = ((bucket - 1) // grid) * grid
                bs = self.cfg.kv_block_size
                last = plan["n_prompt"] - 1
                page = int(job["row"][last])
                if (
                    bucket % bs != 0
                    and self.blocks.refcount(page) > 1
                    and self.blocks.spare_count(rid) > 0
                ):
                    # the diverged boundary page now carries the
                    # registrant's live decode rows — the recompute must
                    # NOT rewrite it in place.  Fork onto the spare the
                    # full-hit plan reserved; no device copy is needed
                    # because the recompute rewrites every row of the
                    # block (prompt rows with identical bits, the rest
                    # with masked zero padding).
                    _, new = self.blocks.cow_fork(rid, last)
                    job["row"][last] = new
                    self._cow_forks += 1
                self._prefix_partial_hits += 1
                self._prefill_tokens_saved += job["q0"]
            if computed:
                break
            q0 = job["q0"]
            if job["state"] is None:
                job["state"] = self._resume_state(plan, q0)
            grid = self._chunk_tokens(bucket)
            c = min((q0 // grid + 1) * grid, bucket) - q0
            bs = self.cfg.kv_block_size
            b0, b1 = q0 // bs, -(-(q0 + c) // bs)
            args = [
                self.params,
                self._cache,
                job["state"],
                jnp.asarray([job["tokens"][q0 : q0 + c]], jnp.int32),
                jnp.asarray(job["row"][: plan["n_prompt"]]),
                jnp.asarray(q0, jnp.int32),
            ]
            if self.int8:
                args.append(jnp.asarray(plan["seeds"][b0:b1]))
            self._cache, job["state"], logits = self._suffix_prefill(
                *args, bucket=bucket
            )
            self._prefill_tokens += c
            self.backend.note_call(
                SP.analog_call_profile("suffix_prefill", tokens=c)
            )
            job["q0"] = q0 + c
            computed = True
            done = job["q0"] == bucket
            if self.sharing:
                # stash the boundary snapshot on the chunk's last block so
                # later admissions can resume (or, on the final chunk with
                # its logits, skip) exactly here; if an in-flight
                # duplicate registered the hash first, its own chunk
                # attaches — ours would be identical bits anyway
                h_last = plan["hashes"][b1 - 1][0]
                if self.blocks.lookup(h_last) == int(job["row"][b1 - 1]):
                    self.blocks.set_payload(
                        h_last, (logits if done else None, job["state"])
                    )
            if not done:
                break
            self._cache = self._state_insert(
                self._cache, job["state"], req.slot
            )
            tok0 = self._sample0(logits, job["rkey"])
            self.backend.note_call(SP.analog_call_profile("sample0"))
            self._prefills += 1
            self._complete_job(rid, job, tok0)
            emitted.append((rid, req.output[-1]))

    def tick(self) -> list[tuple[int, int]]:
        """One engine iteration: admit, advance the (chunked) prefill
        pipeline, then one batched decode step for the decoding slots.

        A compute-overriding backend (sim_faulty) is installed
        process-wide for the duration of the tick (exception-safe), so
        any trace this tick causes picks up its faulty math; the
        degradation policy updates once per tick, after detections and
        the canary have spoken.

        Returns the (rid, token) pairs emitted during this tick.
        """
        ctx = (
            BK.use_backend(self.backend)
            if getattr(self.backend, "overrides_compute", False)
            else contextlib.nullcontext()
        )
        with ctx:
            self._tick_dirty = 0
            self._tick_canary = None
            try:
                return self._tick_inner()
            finally:
                self._policy_update()

    def _tick_inner(self) -> list[tuple[int, int]]:
        t_start = time.perf_counter()
        emitted: list[tuple[int, int]] = []
        if self._injector is not None:
            self._injector.fire(self, self._ticks)
        self._ticks += 1
        if self.paged:
            self._fault_pass()
        # deadline pass: expired requests evict in whatever state they
        # are — queued, mid-chunked-prefill (job + pages dropped
        # atomically), or decoding
        expired = self.sched.expired(time.perf_counter())
        for req in expired:
            self._evict_request(req, "deadline", time.perf_counter())
        gate = self._try_reserve_blocks if self.paged else None
        pol = self.cfg.degradation
        shed = (
            pol.shed_priority_above
            if pol is not None and self._degrade_level >= 3
            else None
        )
        for req in self.sched.admit(gate, shed_priority_above=shed):
            self._admit_one(req)
            if not self.paged:
                emitted.append((req.rid, req.output[-1]))
        if self.paged and self.cfg.enable_preemption:
            self._preempt_pass()
        if self.paged:
            self._prefill_tick(emitted)
        active = self.sched.active()
        # speculate only when every draft write stays inside max_len —
        # near-capacity tails fall back to plain single-token ticks, so
        # an overrun can never clamp into a slot's live final block —
        # and only below degradation level 1 (a k-deep draft multiplies
        # one bad logit row's blast radius by k)
        spec_now = (
            bool(active) and self.spec_k > 0 and self._spec_viable(active)
            and self._degrade_level < 1
        )
        if active and self.sharing:
            self._cow_pass(active, self.spec_k if spec_now else 1)
        if active:
            t_dec = time.perf_counter()
            if spec_now:
                self._spec_tick(active, emitted)
                self._decode_time += time.perf_counter() - t_dec
                self._busy_time += time.perf_counter() - t_start
                return emitted
            sane_np = None
            if self.paged:
                w = self._window_blocks(active)
                r_eff = self._redundant_effective()
                self._cache, nxt, sane = self._get_serve_step(r_eff)(
                    self.params,
                    self._cache,
                    self._put(self._table[:, :w], "table"),
                    self._put(self._tokens, "slot_vec"),
                    self._put(self._req_keys, "slot_keys"),
                    self._put(self._steps, "slot_vec"),
                )
                sane_np = np.asarray(sane)
                self._host_pos += 1  # mirrors the step's pos+1, every slot
            else:
                r_eff = 1
                self._cache, nxt = self._serve_step(
                    self.params,
                    self._cache,
                    jnp.asarray(self._tokens),
                    jnp.asarray(self._req_keys),
                    jnp.asarray(self._steps),
                )
            nxt_np = np.asarray(nxt)  # device sync — decode_time is honest
            # logical decode work this step: one forward + one sampling
            # decision per ACTIVE slot (idle-slot padding is not logical
            # work — counting it would break batch-composition
            # invariance); redundant comparator re-reads beyond the first
            # are priced per active slot the same way
            self.backend.note_call(
                SP.analog_call_profile(
                    "serve_step", batch=len(active),
                    redundant=(r_eff - 1) * len(active),
                )
            )
            now = time.perf_counter()
            self._decode_time += now - t_dec
            self._occ_sum += len(active) / self.cfg.max_batch
            self._decode_steps += 1
            for req in active:
                slot = req.slot
                if sane_np is not None and int(sane_np[slot]):
                    # logit-sanity trip (analog garbage / injected
                    # fault): evict with the matching typed reason
                    # instead of publishing a garbage token — the slot
                    # frees, serving continues
                    self._evict_request(
                        req,
                        SP.SANITY_REASONS.get(int(sane_np[slot]), "nan"),
                        now,
                    )
                    continue
                t = int(nxt_np[slot])
                rep = self._replay.get(req.rid)
                if rep is not None:
                    # teacher-force the next already-published token (the
                    # sampled one is bitwise the same in a fault-free
                    # run); nothing re-records or re-publishes
                    self._tokens[slot] = rep.pop(0)
                    if not rep:
                        del self._replay[req.rid]
                    self._steps[slot] += 1
                    continue
                self._tokens[slot] = t
                self._steps[slot] += 1
                self._total_tokens += 1
                self.sched.record_token(req, t, self.cfg.eos_token, now)
                self._release_if_done(req)
                emitted.append((req.rid, t))
        self._busy_time += time.perf_counter() - t_start
        return emitted

    # ---- degraded-device serving: detection + mitigation + policy ----

    def _fault_pass(self) -> None:
        """Per-tick fault housekeeping, before any scheduling decision:
        advance the backend's fault clock, rebuild stale entry points,
        and fire the known-answer canary on its interval (a failure is a
        detection event and may trigger tile retirement)."""
        bk = self.backend
        if getattr(bk, "overrides_compute", False):
            bk.advance_clock(1)
        self._check_fault_version()
        ci = self.cfg.canary_interval
        if not ci or self._ticks % ci:
            return
        self._canary_probes += 1
        if self._canary_expected is None:
            self._canary_expected = KOPS.canary_expected()
        key = jax.random.fold_in(self._base_key, 0xCA9A30 + self._ticks)
        got = np.asarray(self._canary(key), np.float32)
        exp = self._canary_expected
        scale = max(float(np.max(np.abs(exp))), 1e-9)
        rel = float(np.max(np.abs(got - exp))) / scale
        passed = rel <= self.cfg.canary_threshold
        self._tick_canary = passed
        if passed:
            return
        self._canary_failures += 1
        self._tick_dirty += 1
        thr = self.cfg.tile_retire_threshold
        if thr > 0.0 and hasattr(bk, "retire_tiles"):
            if bk.retire_tiles(thr):
                # retirement changed the stuck masks baked into traces
                self._check_fault_version()

    def _redundant_effective(self) -> int:
        """Redundant-read factor for this tick's decode step: the config
        base, raised to the policy's factor at degradation level >= 2."""
        r = self._redundant_base
        pol = self.cfg.degradation
        if pol is not None and self._degrade_level >= 2 and self.mcfg.wta_head:
            r = max(r, pol.redundant_reads)
        return r

    def _degrade_transition(self, to: int, why: str) -> None:
        self._degraded_transitions.append({
            "tick": self._ticks,
            "from": self._degrade_level,
            "to": to,
            "why": why,
        })
        self._degrade_level = to

    def _policy_update(self) -> None:
        """End-of-tick DegradationPolicy step: fold this tick's detection
        events (sanity evictions + canary failure) into the streaks and
        move at most one rung.  Escalation needs ``trip_after``
        consecutive dirty ticks; de-escalation needs ``recover_after``
        consecutive clean canary PASSES — absent a canary there is no
        positive evidence of recovery, so degradation is one-way."""
        pol = self.cfg.degradation
        if pol is None:
            return
        if self._tick_dirty:
            self._dirty_streak += 1
            self._clean_streak = 0
        else:
            self._dirty_streak = 0
            if self._tick_canary is True:
                self._clean_streak += 1
        if self._dirty_streak >= pol.trip_after and self._degrade_level < 3:
            self._degrade_transition(
                self._degrade_level + 1, "fault_pressure"
            )
            self._dirty_streak = 0
        elif (
            self._clean_streak >= pol.recover_after
            and self._degrade_level > 0
        ):
            self._degrade_transition(
                self._degrade_level - 1, "canary_recovered"
            )
            self._clean_streak = 0

    def _spec_viable(self, active: list[Request]) -> bool:
        """True when a k-deep draft run cannot write past ``max_len`` for
        any decoding slot (overruns past a slot's RESERVATION are fine —
        they land in the trash page — but a write past the table width
        would clamp into the slot's own last block)."""
        lim = self.cfg.max_len - self.spec_k
        return all(int(self._host_pos[r.slot]) <= lim for r in active)

    def _spec_tick(self, active: list[Request], emitted: list) -> None:
        """One fused self-speculative round for every decoding slot.

        Device side: ONE dispatch drafts k chained tokens per slot (the
        plain decode cell, K/V into the reserved pages, identical int8
        ``quant_step`` trajectory) and re-decodes the run read-only from
        the pre-draft snapshot (see :func:`SP.make_paged_spec_round`).
        Host side: per slot, accept drafts until the verifier's resample
        disagrees — the disagreeing resample is itself the corrected
        token, exactly what the plain engine would have emitted, so
        greedy and per-slot-keyed WTA streams stay byte-identical to
        ``speculate_k=0``.  A rejected (or short) round rolls the slot
        back through the verifier's per-step states; drafted K/V beyond
        the rollback position is masked dead rows.  The NaN guard moves
        to draft depth: a non-finite draft step truncates the usable run
        and, if everything before it accepted, evicts with reason
        ``"nan"`` exactly like a plain tick would have.
        """
        k = self.spec_k
        w = self._window_blocks(active, k)
        pre_pos = self._host_pos.copy()
        pre_steps = self._steps.copy()
        self._cache, dtoks, doks, vtoks, _voks, vstates = self._spec_round(
            self.params,
            self._cache,
            self._put(self._table[:, :w], "table"),
            self._put(self._tokens, "slot_vec"),
            self._put(self._req_keys, "slot_keys"),
            self._put(self._steps, "slot_vec"),
        )
        d_np = np.asarray(dtoks)   # device sync — decode_time is honest
        # one fused round: k drafted tokens per active slot (forwarded,
        # sampled, K/V written) + k verify re-decodes (forwarded, sampled,
        # read-only).  Rejected drafts stay in the tally — that energy was
        # spent whether or not a token publishes.
        self.backend.note_call(
            SP.analog_call_profile("spec_round", batch=len(active), k=k)
        )
        dok_np = np.asarray(doks)
        v_np = np.asarray(vtoks)
        self._host_pos += k  # mirrors the draft scan's k pos bumps
        now = time.perf_counter()
        self._occ_sum += len(active) / self.cfg.max_batch
        self._decode_steps += 1
        self._spec_rounds += 1
        for req in active:
            slot = req.slot
            # usable drafts stop at the first non-finite draft step
            m = k
            for j in range(k):
                if not bool(dok_np[slot, j]):
                    m = j
                    break
            if m == 0:
                self._evict_request(req, "nan", now)
                continue
            self._spec_drafted += m
            req.spec_drafted += m
            req.spec_high = max(req.spec_high, int(pre_pos[slot]) + m - 1)
            e = 0              # tokens consumed from this round
            done = False
            rollback_at = None  # verify-state index to roll back to
            for i in range(m):
                t_d = int(d_np[slot, i])
                rep = self._replay.get(req.rid)
                if rep is not None:
                    # teacher-forced replay of already-published tokens:
                    # consumed without re-recording.  A forced token that
                    # disagrees with its draft (possible only under
                    # injected faults) truncates the round right there —
                    # the verifier state after consuming the inputs so
                    # far is still published-stream-exact
                    forced = rep.pop(0)
                    if not rep:
                        del self._replay[req.rid]
                    self._tokens[slot] = forced
                    e += 1
                    if forced != t_d:
                        rollback_at = i
                        break
                    continue
                t = int(v_np[slot, i])  # == draft when accepted
                self._tokens[slot] = t
                e += 1
                accepted = t == t_d
                if accepted:
                    self._spec_accepted += 1
                    req.spec_accepted += 1
                self._total_tokens += 1
                done = self.sched.record_token(
                    req, t, self.cfg.eos_token, now
                )
                emitted.append((req.rid, t))
                if done:
                    break
                if not accepted:
                    rollback_at = i
                    break
            self._spec_emitted += e
            if done:
                self._release_if_done(req)
                continue
            if rollback_at is not None:
                # rejected tail: rewind pos + recurrent/SSM state to the
                # verifier's recomputed state after the last consumed
                # input — bitwise the plain engine's state at that point
                self._cache = self._spec_rollback(
                    self._cache,
                    vstates,
                    self._put(np.int32(rollback_at), "replicated"),
                    self._put(np.int32(slot), "replicated"),
                )
                self._host_pos[slot] = int(pre_pos[slot]) + e
            elif m < k:
                # every usable draft accepted and the NEXT draft step went
                # non-finite from exactly this state — the plain engine's
                # next tick would have hit the same logits
                self._evict_request(req, "nan", now)
                continue
            self._steps[slot] = int(pre_steps[slot]) + e

    def _cow_pass(self, active: list[Request], span: int = 1) -> None:
        """Resolve copy-on-write state BEFORE the batched decode step.

        Each active slot is about to write its K/V row into block
        ``pos // block_size`` of its table — or, for a speculative round,
        into every block the k-deep draft run touches
        (``span`` > 1; only the FIRST can be shared, since decode-budget
        blocks past the prompt boundary are always freshly reserved, so
        the one-spare-per-request COW invariant holds unchanged).  If a
        write-span page is still shared
        (refcount > 1) the writer forks: its reserved spare page gets a
        device-side copy of the pristine content and the table row is
        repointed, so the write lands privately while the other owners
        keep reading the original.  A *sole* owner writes in place, but
        its page's index entry (if any) is dropped first — the content is
        about to diverge from the registered hash, and a stale entry
        would hand corrupted blocks to later admissions.

        The one writer per shared page that holds no spare is its original
        registrant (sharers always reserve a spare at the gate); every
        co-writer of that page forks in this same pass — all copies read
        the still-pristine page because the in-place write only happens
        inside the decode step, after this pass completes.
        """
        bs = self.cfg.kv_block_size
        for req in active:
            p = int(self._host_pos[req.slot])
            last = min((p + span - 1) // bs, self._max_blocks - 1)
            for wb in range(p // bs, last + 1):
                page = int(self._table[req.slot, wb])
                if page < self.blocks.n_reserved:
                    continue  # trash row of an already-evicted slot
                if (
                    self.blocks.refcount(page) > 1
                    and self.blocks.spare_count(req.rid) > 0
                ):
                    _, new = self.blocks.cow_fork(req.rid, wb)
                    self._cache = self._page_copy(self._cache, page, new)
                    self._table[req.slot, wb] = new
                    self._cow_forks += 1
                else:
                    self.blocks.deregister(page)  # no-op if unregistered

    def _window_blocks(self, active: list[Request], span: int = 1) -> int:
        """Decode window width in blocks for this tick.

        The smallest power-of-two block count covering every active slot's
        current position (plus the ``span`` positions a speculative round
        writes) — power-of-two bucketing keeps the number of distinct
        (table-width) step compiles logarithmic in max_len while the
        window still tracks the *occupied* prefix, not max_len."""
        bs = self.cfg.kv_block_size
        need = max(
            (int(self._host_pos[r.slot]) + span - 1) // bs + 1
            for r in active
        )
        w = 1
        while w < need:
            w *= 2
        return min(w, self._max_blocks)

    def run(self) -> dict[int, list[int]]:
        """Drain queue + slots; returns {rid: generated tokens}."""
        while self.sched.has_work():
            self.tick()
        return {
            r.rid: r.output
            for r in self.sched.all_requests()
            if r.state is RequestState.DONE
        }

    def step(self) -> list[list[int]]:
        """Legacy API: drain and return newly completed outputs in
        submission order (the old static engine's ``step()`` contract)."""
        before = {
            r.rid
            for r in self.sched.all_requests()
            if r.state is RequestState.DONE
        }
        self.run()
        return [
            r.output
            for r in self.sched.all_requests()
            if r.state is RequestState.DONE and r.rid not in before
        ]

    def metrics(self) -> ServingMetrics:
        done = [
            r
            for r in self.sched.all_requests()
            if r.state is RequestState.DONE
        ]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        evictions: dict[str, int] = {}
        for r in done:
            if r.done_reason:
                evictions[r.done_reason] = (
                    evictions.get(r.done_reason, 0) + 1
                )
        by_class: dict[int, dict] = {}
        for pr in sorted({r.priority for r in done}):
            rs = [r for r in done if r.priority == pr]
            tt = [r.ttft for r in rs if r.ttft is not None]
            lat = [
                r.done_time - r.submit_time
                for r in rs
                if r.done_time is not None
            ]
            by_class[pr] = {
                "n": len(rs),
                "ttft_p50_ms": _pctl(tt, 50) * 1e3,
                "ttft_p99_ms": _pctl(tt, 99) * 1e3,
                "latency_p50_ms": _pctl(lat, 50) * 1e3,
                "latency_p99_ms": _pctl(lat, 99) * 1e3,
            }
        wall = self._busy_time
        analog = self.backend.snapshot(published_tokens=self._total_tokens)
        return ServingMetrics(
            completed=len(done),
            total_tokens=self._total_tokens,
            wall_time=wall,
            tokens_per_s=self._total_tokens / max(wall, 1e-9),
            ttft_mean=float(np.mean(ttfts)) if ttfts else 0.0,
            ttft_max=float(np.max(ttfts)) if ttfts else 0.0,
            decode_steps=self._decode_steps,
            prefills=self._prefills,
            occupancy_mean=self._occ_sum / max(self._decode_steps, 1),
            decode_time=self._decode_time,
            prefix_hits=self._prefix_hits,
            cow_forks=self._cow_forks,
            prefix_partial_hits=self._prefix_partial_hits,
            prefill_tokens=self._prefill_tokens,
            prefill_tokens_saved=self._prefill_tokens_saved,
            ttft_p50=_pctl(ttfts, 50),
            ttft_p99=_pctl(ttfts, 99),
            preemptions=self._preemptions,
            restores=self._restores,
            spill_drops=self._spill_drops,
            spec_rounds=self._spec_rounds,
            spec_drafted=self._spec_drafted,
            spec_accepted=self._spec_accepted,
            spec_acceptance=(
                self._spec_accepted / max(self._spec_drafted, 1)
            ),
            spec_tokens_per_round=(
                self._spec_emitted / max(self._spec_rounds, 1)
            ),
            evictions=evictions,
            latency_by_class=by_class,
            analog=analog,
            degraded_mode=self._degrade_level,
            canary_probes=self._canary_probes,
            canary_failures=self._canary_failures,
            retired_tiles=int(getattr(self.backend, "retired_tiles", 0)),
            redundant_read_events=analog["redundant_read_events"],
            degraded_transitions=list(self._degraded_transitions),
        )

    def compile_counts(self) -> dict[str, int]:
        """Traced-computation counts per jitted entry point.

        The recompile-guard tests pin these.  Paged: one compile per
        (bucket, suffix-chunk shape) pair for the chunked prefill entry
        point and one per decode window bucket (serve_step) — never one
        per tick, slot, page set, or start position (those are traced).
        The sharing entry points (state_insert, page_copy, sample0)
        compile at most ONCE each over the engine's lifetime: their
        argument shapes are bucket-independent.  Dense: one compile per
        prefill bucket (prefill + insert).

        ``serve_step`` sums over the redundant-read variants: a level-2
        degradation episode adds one compile per (R, window) pair, and
        the healthy artifact is reused when the ladder recovers."""
        if self.paged:
            counts = {
                "serve_step": sum(
                    f._cache_size() for f in self._serve_steps.values()
                )
            }
        else:
            counts = {"serve_step": self._serve_step._cache_size()}
        if self.paged:
            counts["suffix_prefill"] = self._suffix_prefill._cache_size()
            counts["state_insert"] = self._state_insert._cache_size()
            counts["page_copy"] = self._page_copy._cache_size()
            counts["sample0"] = self._sample0._cache_size()
            counts["page_spill"] = self._page_spill._cache_size()
            counts["page_restore"] = self._page_restore._cache_size()
            counts["state_gather"] = self._state_gather._cache_size()
            if self.spec_k:
                counts["spec_round"] = self._spec_round._cache_size()
                counts["spec_rollback"] = (
                    self._spec_rollback._cache_size()
                )
        else:
            counts["prefill"] = self._prefill._cache_size()
            counts["insert"] = self._insert._cache_size()
        return counts


class StaticServingEngine:
    """The pre-continuous-batching reference: whole batch prefilled
    together (prompts left-padded to the batch max), every slot held until
    the LAST request of the batch finishes.  Kept as the equivalence oracle
    for tests and the occupancy baseline for benchmarks."""

    def __init__(self, params, model_cfg: ModelConfig, cfg: ServeConfig):
        self.params = params
        self.mcfg = model_cfg
        self.cfg = cfg
        self.fns = get_model_fns(model_cfg)
        self._serve_step = jax.jit(
            SP.make_serve_step(model_cfg), donate_argnums=(1,)
        )
        self._queue: list[tuple[list[int], int, float]] = []
        self._key = jax.random.PRNGKey(cfg.seed)
        self._occ_sum = 0.0
        self._decode_steps = 0
        self._total_tokens = 0
        self._busy_time = 0.0
        self._decode_time = 0.0
        self._ttfts: list[float] = []
        self._completed = 0

    def submit(
        self,
        prompt_tokens: Sequence[int],
        max_new_tokens: Optional[int] = None,
        submit_time: Optional[float] = None,
    ) -> None:
        budget = (
            self.cfg.max_new_tokens if max_new_tokens is None
            else max_new_tokens
        )
        if budget < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {budget}")
        if len(prompt_tokens) + budget > self.cfg.max_len:
            raise ValueError(
                f"prompt {len(prompt_tokens)} + {budget} new tokens "
                f"exceeds cache max_len={self.cfg.max_len}"
            )
        self._queue.append(
            (
                list(prompt_tokens),
                budget,
                submit_time if submit_time is not None
                else time.perf_counter(),
            )
        )

    def pending(self) -> int:
        """Requests queued for a future batch wave."""
        return len(self._queue)

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def step(self) -> list[list[int]]:
        """Serve one batch from the queue; returns generated token lists."""
        if not self._queue:
            return []
        t_start = time.perf_counter()
        batch = self._queue[: self.cfg.max_batch]
        self._queue = self._queue[self.cfg.max_batch :]
        prompts = [p for p, _, _ in batch]
        budgets = [m for _, m, _ in batch]
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        # decode starts at the batch-max padded length for EVERY slot, so a
        # short prompt co-batched with a long one can overflow the cache
        # even when its own (prompt + budget) fit at submit time
        worst = plen + max(budgets)
        if worst > self.cfg.max_len:
            raise ValueError(
                f"padded prompt window {plen} + max budget {max(budgets)} "
                f"= {worst} exceeds cache max_len={self.cfg.max_len}"
            )
        toks = np.asarray(
            [left_pad(p, plen) for p in prompts], np.int32
        )
        cache, logits = self.fns.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.mcfg,
            self.cfg.max_len,
        )
        out = [[] for _ in range(b)]
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = np.zeros(b, bool)
        now = time.perf_counter()
        for _, _, t_sub in batch:
            self._ttfts.append(now - t_sub)
        for _ in range(max(budgets)):
            for i in range(b):
                if not done[i]:
                    t = int(token[i])
                    out[i].append(t)
                    self._total_tokens += 1
                    if t == self.cfg.eos_token or len(out[i]) >= budgets[i]:
                        done[i] = True
            if done.all():
                break
            key = self._next_key() if self.mcfg.wta_head else None
            t_dec = time.perf_counter()
            cache, token = self._serve_step(self.params, cache, token, key)
            token.block_until_ready()
            self._decode_time += time.perf_counter() - t_dec
            # slots stay held for the whole batch: idle ones count against
            # occupancy, which is the cost continuous batching removes
            self._occ_sum += (b - int(done.sum())) / self.cfg.max_batch
            self._decode_steps += 1
        self._completed += b
        self._busy_time += time.perf_counter() - t_start
        return out

    def run(self) -> list[list[int]]:
        outs: list[list[int]] = []
        while self._queue:
            outs.extend(self.step())
        return outs

    def metrics(self) -> ServingMetrics:
        wall = self._busy_time
        return ServingMetrics(
            completed=self._completed,
            total_tokens=self._total_tokens,
            wall_time=wall,
            tokens_per_s=self._total_tokens / max(wall, 1e-9),
            ttft_mean=float(np.mean(self._ttfts)) if self._ttfts else 0.0,
            ttft_max=float(np.max(self._ttfts)) if self._ttfts else 0.0,
            decode_steps=self._decode_steps,
            prefills=self._completed,
            occupancy_mean=self._occ_sum / max(self._decode_steps, 1),
            decode_time=self._decode_time,
        )
