"""Batched serving engine: request queue → padded batch prefill → decode.

Serving-side integration of the paper: with ``cfg.wta_head`` the sampler is
the WTA stochastic SoftMax circuit — per emitted token, T comparator-bank
decision trials vote and the majority wins (§III-B/C).  Repeated-vote
majority is exactly the paper's accuracy-recovery mechanism (Fig. 6), here
applied to LM decoding; greedy argmax is the digital baseline.

The engine is deliberately simple (static batch, right-padded prompts,
synchronous decode loop) but complete: queueing, batching, EOS handling,
per-request detokenized outputs.  Continuous batching would slot into
``step()`` without touching the model code.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.specs import make_serve_step
from repro.models import ModelConfig, get_model_fns


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_new_tokens: int = 32
    max_len: int = 512
    eos_token: int = -1     # -1: never stop early
    seed: int = 0


class ServingEngine:
    def __init__(self, params, model_cfg: ModelConfig, cfg: ServeConfig):
        self.params = params
        self.mcfg = model_cfg
        self.cfg = cfg
        self.fns = get_model_fns(model_cfg)
        self._serve_step = jax.jit(
            make_serve_step(model_cfg), donate_argnums=(1,)
        )
        self._queue: list[Sequence[int]] = []
        self._key = jax.random.PRNGKey(cfg.seed)

    def submit(self, prompt_tokens: Sequence[int]) -> None:
        self._queue.append(list(prompt_tokens))

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def step(self) -> list[list[int]]:
        """Serve one batch from the queue; returns generated token lists."""
        if not self._queue:
            return []
        batch_prompts = self._queue[: self.cfg.max_batch]
        self._queue = self._queue[self.cfg.max_batch :]
        b = len(batch_prompts)
        # right-align prompts into a fixed prompt window (left-pad with 0)
        plen = max(len(p) for p in batch_prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(batch_prompts):
            toks[i, plen - len(p) :] = p
        batch = {"tokens": jnp.asarray(toks)}
        cache, logits = self.fns.prefill(
            self.params, batch, self.mcfg, self.cfg.max_len
        )
        out = [[] for _ in range(b)]
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        done = np.zeros(b, bool)
        for _ in range(self.cfg.max_new_tokens):
            for i in range(b):
                if not done[i]:
                    t = int(token[i])
                    out[i].append(t)
                    if t == self.cfg.eos_token:
                        done[i] = True
            if done.all():
                break
            key = self._next_key() if self.mcfg.wta_head else None
            cache, token = self._serve_step(self.params, cache, token, key)
        return out
