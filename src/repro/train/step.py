"""The jitted training step: loss → (micro-batched, optionally compressed)
gradients → AdamW update.

This single function is what the multi-pod dry-run lowers for every
(arch × train shape): data parallelism comes from batch sharding, tensor
parallelism from the param/activation rules (launch/sharding.py), and the
optimizer update runs on the FSDP-sharded states in place (donated).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, get_model_fns
from repro.optim import (
    AdamWConfig,
    CompressState,
    adamw_init,
    adamw_update,
    compress_grads,
    init_compress,
    warmup_cosine,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    microbatches: int = 1
    compress_grads: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    seed: int = 0


class TrainState(NamedTuple):
    params: Any
    opt: Any                       # AdamWState
    compress: Optional[CompressState]
    step: jax.Array
    rng: jax.Array


def init_train_state(
    key: jax.Array, model_cfg: ModelConfig, train_cfg: TrainConfig
) -> TrainState:
    fns = get_model_fns(model_cfg)
    params = fns.init(key, model_cfg)
    return TrainState(
        params=params,
        opt=adamw_init(params, train_cfg.opt),
        compress=init_compress(params) if train_cfg.compress_grads else None,
        step=jnp.zeros((), jnp.int32),
        rng=jax.random.fold_in(key, 1),
    )


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    fns = get_model_fns(model_cfg)
    needs_key = model_cfg.analog.mode != "digital"

    def loss_fn(params, batch, key):
        return fns.loss(params, batch, model_cfg, key if needs_key else None)

    def train_step(state: TrainState, batch: dict):
        step_key = jax.random.fold_in(state.rng, state.step)
        nmb = train_cfg.microbatches

        if nmb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state.params, batch, step_key)
            comp = state.compress
            if comp is not None:
                grads, comp = compress_grads(
                    grads, comp, jax.random.fold_in(step_key, 13)
                )
        else:
            # micro-batched accumulation; per-microbatch compression models a
            # compressed cross-replica reduction with error feedback.
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape((nmb, b // nmb) + x.shape[1:])

            mb = jax.tree.map(slice_mb, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def body(carry, xs):
                acc, comp, lsum = carry
                mbatch, i = xs
                kmb = jax.random.fold_in(step_key, i)
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mbatch, kmb
                )
                if comp is not None:
                    g, comp = compress_grads(
                        g, comp, jax.random.fold_in(kmb, 13)
                    )
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / nmb, acc, g
                )
                return (acc, comp, lsum + l / nmb), None

            (grads, comp, loss), _ = jax.lax.scan(
                body,
                (zero_g, state.compress, jnp.zeros((), jnp.float32)),
                (mb, jnp.arange(nmb)),
            )
            metrics = {"loss": loss}

        lr_scale = warmup_cosine(
            state.step,
            warmup=train_cfg.warmup_steps,
            total=train_cfg.total_steps,
        )
        params, opt, opt_metrics = adamw_update(
            train_cfg.opt,
            state.params,
            grads,
            state.opt,
            lr_scale=lr_scale,
            rng=jax.random.fold_in(step_key, 7)
            if train_cfg.opt.stochastic_rounding
            else None,
        )
        metrics = {**metrics, **opt_metrics}
        new_state = TrainState(
            params=params,
            opt=opt,
            compress=comp,
            step=state.step + 1,
            rng=state.rng,
        )
        return new_state, metrics

    return train_step
