"""Fault-tolerant training loop.

Production posture for thousands of nodes, exercised here at container
scale and in tests:

  * checkpoint/restart — async CheckpointManager, atomic writes, auto-resume
    from the latest step on (re)start; the data pipeline is stateless so a
    resumed run consumes exactly the batches it would have (no iterator
    state to restore).
  * fault injection — FAULT_INJECT_STEP env/arg raises mid-run; the outer
    retry loop reloads the last checkpoint and continues (tests assert the
    final loss trajectory matches an uninterrupted run).
  * straggler mitigation — per-step wall-time EMA; steps slower than
    ``straggler_factor``× the EMA are logged and counted.  On a real fleet
    this signal feeds the reschedule/evict controller; here it drives logs
    and metrics (and tests inject a slow step to see it fire).
  * elastic scaling — checkpoints are mesh-agnostic (host-gathered); on
    restart the loop re-shards into whatever mesh the surviving devices
    form (see checkpoint.load_checkpoint(shardings=...)).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
)
from repro.models.config import ModelConfig
from repro.testing import (
    InjectedFault,
    StepFaultInjector,
    fault_step_from_env,
)
from .step import TrainConfig, TrainState, init_train_state, make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "ckpts"
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 2.0
    fault_inject_step: Optional[int] = None  # raise once at this step
    max_restarts: int = 3
    seed: int = 0


class StragglerMonitor:
    def __init__(self, factor: float):
        self.factor = factor
        self.ema: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        if slow:
            self.flagged += 1
            log.warning(
                "straggler step: %.3fs vs EMA %.3fs (flagged=%d)",
                dt, self.ema, self.flagged,
            )
        return slow


# backward-compat alias: tests and callers catch the shared exception type
_InjectedFault = InjectedFault


def run(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig,
    loop_cfg: LoopConfig,
    batch_fn: Callable[[int], dict],
    state_shardings=None,
    step_fn=None,
    state: Optional[TrainState] = None,
) -> tuple[TrainState, dict]:
    """Run (or resume) training; returns (final state, stats)."""
    step_fn = step_fn or jax.jit(make_train_step(model_cfg, train_cfg),
                                 donate_argnums=(0,))
    mgr = CheckpointManager(loop_cfg.ckpt_dir)
    monitor = StragglerMonitor(loop_cfg.straggler_factor)
    stats = {"losses": [], "restarts": 0, "stragglers": 0}

    injector = StepFaultInjector(
        fault_step_from_env(loop_cfg.fault_inject_step)
    )

    restarts = 0
    while True:
        try:
            if state is None:
                last = latest_step(loop_cfg.ckpt_dir)
                fresh = init_train_state(
                    jax.random.PRNGKey(loop_cfg.seed), model_cfg, train_cfg
                )
                if last is not None:
                    log.info("resuming from checkpoint step %d", last)
                    state = load_checkpoint(
                        loop_cfg.ckpt_dir, last,
                        jax.eval_shape(lambda: fresh),
                        shardings=state_shardings,
                    )
                    state = jax.tree.map(jax.numpy.asarray, state)
                else:
                    state = fresh

            while int(state.step) < loop_cfg.steps:
                step = int(state.step)
                batch = batch_fn(step)
                t0 = time.time()
                injector.check(step)  # raises InjectedFault exactly once
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                if monitor.observe(dt):
                    stats["stragglers"] += 1
                loss = float(metrics["loss"])
                stats["losses"].append((step, loss))
                if step % loop_cfg.log_every == 0:
                    log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
                if (step + 1) % loop_cfg.ckpt_every == 0:
                    mgr.save_async(step + 1, state)
            break
        except _InjectedFault as e:
            restarts += 1
            stats["restarts"] = restarts
            log.warning("fault: %s — restart %d", e, restarts)
            if restarts > loop_cfg.max_restarts:
                raise
            mgr.wait()
            state = None  # force reload from latest checkpoint

    mgr.wait()
    mgr.save_async(int(state.step), state)
    mgr.wait()
    return state, stats
