"""Mamba-2 (SSD — state-space duality) mixer block (arXiv:2405.21060).

Chunked SSD algorithm: within-chunk interactions are computed in quadratic
attention-like form (chunk length Q kept MXU-friendly); across chunks a
recurrent state (B, H, P, N) is carried through a lax.scan.  Attention-free:
decode keeps an O(1) state (this is why mamba2 runs the long_500k shape).

in/out projections route through core.analog; the SSM gating branch (silu)
is noted partially applicable to the paper's sigmoid neurons (DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import parallel
from repro.core import analog as A
from .config import ModelConfig
from .layers import dtype_of, rmsnorm, init_rmsnorm


class SSMCache(NamedTuple):
    conv: jax.Array   # (L, B, K-1, conv_channels)
    state: jax.Array  # (L, B, H, P, N)


def init_mamba(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_nheads
    p_dim = cfg.ssm_headdim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    conv_ch = di + 2 * n  # x, B, C all go through the causal conv
    proj_out = 2 * di + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": (
            jax.random.normal(ks[0], (d, proj_out), jnp.float32) * d**-0.5
        ).astype(dt),
        "conv_w": (
            jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
            * 0.1
        ).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h).astype(jnp.float32)
        ),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": init_rmsnorm(di),
        "out_proj": (
            jax.random.normal(ks[2], (di, d), jnp.float32) * di**-0.5
        ).astype(dt),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di : 2 * di]
    b = zxbcdt[..., 2 * di : 2 * di + n]
    c = zxbcdt[..., 2 * di + n : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, x, b, c, dt


def _causal_conv(
    u: jax.Array, w: jax.Array, b: jax.Array, cache=None
) -> jax.Array:
    """Depthwise causal conv1d.  u: (B,S,C), w: (K,C).  f32 accumulation so
    the decode step (which recomputes taps in f32) matches bit-for-bit.

    ``cache`` (B, K-1, C), when given, replaces the zero left-pad with
    the raw conv inputs preceding the chunk (a resumable prefill); a zero
    cache is value-identical to the zero pad, which is what keeps
    single-chunk prefills bit-identical to the monolithic path."""
    k = w.shape[0]
    uf = u.astype(jnp.float32)
    if cache is None:
        pad = jnp.pad(uf, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([cache.astype(jnp.float32), uf], axis=1)
    out = jnp.zeros_like(uf)
    wf = w.astype(jnp.float32)
    for i in range(k):  # K is 4: unrolled taps, no conv primitive needed
        out = out + pad[:, i : i + u.shape[1], :] * wf[i]
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(u.dtype)


def ssd_chunked(
    x: jax.Array,    # (B,S,H,P) pre-scaled by dt
    a_step: jax.Array,  # (B,S,H) per-step log-decay
    bmat: jax.Array,  # (B,S,N)
    cmat: jax.Array,  # (B,S,N)
    chunk: int,
    h0: Optional[jax.Array] = None,
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Core SSD recurrence over chunks.  Returns (y (B,S,H,P), final state
    (B,H,P,N)).  Sequences not divisible by ``chunk`` are zero-padded with
    identity dynamics (log-decay 0, zero input) so outputs and the final
    state are unaffected."""
    s_orig = x.shape[1]
    pad = (-s_orig) % chunk
    if pad:
        pz = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, a_step, bmat, cmat = pz(x), pz(a_step), pz(bmat), pz(cmat)
    a_cum = a_step
    bsz, s, nh, pd = x.shape
    n = bmat.shape[-1]
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, nh, pd).transpose(1, 0, 2, 3, 4)
    ac = a_cum.reshape(bsz, nc, chunk, nh).transpose(1, 0, 2, 3)
    bc = bmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, pd, n), jnp.float32)

    def step(h, inp):
        xi, ai, bi, ci = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(ai, axis=1)  # (B,Q,H) within-chunk
        # off-diagonal: contribution of the carried state
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", ci, h, jnp.exp(cum))
        # within-chunk quadratic form; mask BEFORE exp — the upper triangle
        # has positive exponents that overflow (inf·0 = NaN otherwise)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,K,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
        li = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("bqn,bkn->bqk", ci, bi)
        att = scores[:, :, :, None] * li
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", att, xi)
        # state update: h' = decay_total·h + Σ_j exp(cum_Q - cum_j) B_j x_j
        dec_last = jnp.exp(cum[:, -1, :])  # (B,H)
        dec_rest = jnp.exp(cum[:, -1:, :] - cum)  # (B,Q,H)
        h_add = jnp.einsum("bqh,bqn,bqhp->bhpn", dec_rest, bi, xi)
        h_new = h * dec_last[:, :, None, None] + h_add
        return h_new, y_off + y_diag

    hf, yc = jax.lax.scan(
        step, h0, (xc, ac, bc, cc), unroll=True if unroll else 1
    )
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, pd)
    return y[:, :s_orig], hf


def mamba_apply(
    p: dict,
    u: jax.Array,  # (B,S,D)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    bsz, s, d = u.shape
    acfg = cfg.analog
    pcfg = (
        acfg.with_mode("analog_linear")
        if acfg.mode == "analog_stochastic"
        else acfg
    )
    k1 = k2 = None
    if key is not None:
        k1, k2 = jax.random.split(key)
    zxbcdt = A.analog_matmul(pcfg, k1, u, p["in_proj"])
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    di, n = cfg.d_inner, cfg.ssm_state
    x = conv_out[..., :di]
    bmat = conv_out[..., di : di + n].astype(jnp.float32)
    cmat = conv_out[..., di + n :].astype(jnp.float32)

    nh, pd = cfg.ssm_nheads, cfg.ssm_headdim
    xh = x.reshape(bsz, s, nh, pd).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,) negative
    log_decay = dtf * a  # (B,S,H)
    xdt = xh * dtf[..., None]
    y, _ = ssd_chunked(
        xdt, log_decay, bmat, cmat, cfg.ssm_chunk, unroll=cfg.cost_exact
    )
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    out = A.analog_matmul(pcfg, k2, y, p["out_proj"])
    return parallel.shard(out, ("batch", "seq", "embed"))


def mamba_prefill(
    p: dict,
    u: jax.Array,  # (B,S,D)
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Forward pass that also returns decode-cache state:
    (y (B,S,D), conv input tail (B,K-1,C), final ssm state (B,H,P,N)).

    Delegates to :func:`mamba_prefill_chunk` with zeroed carry — the
    monolithic prefill IS the single-chunk case, so the two can never
    drift apart numerically (the dense-vs-paged byte-identity anchor)."""
    bsz = u.shape[0]
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return mamba_prefill_chunk(
        p, u,
        jnp.zeros((bsz, cfg.ssm_conv - 1, conv_ch), u.dtype),
        jnp.zeros(
            (bsz, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32,
        ),
        cfg,
    )


def mamba_prefill_chunk(
    p: dict,
    u: jax.Array,           # (B,S,D) — one suffix chunk
    conv_cache: jax.Array,  # (B,K-1,C) raw conv inputs preceding the chunk
    state0: jax.Array,      # (B,H,P,N) f32 SSM state entering the chunk
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One chunk of a resumable prefill: :func:`mamba_prefill` math with
    the conv window and SSM state carried across chunks.  Returns
    (y (B,S,D), new conv tail (B,K-1,C), final ssm state (B,H,P,N))."""
    bsz, s, d = u.shape
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    new_tail = jnp.concatenate(
        [conv_cache.astype(conv_in.dtype), conv_in], axis=1
    )[:, -(cfg.ssm_conv - 1) :, :]
    conv_out = _causal_conv(
        conv_in, p["conv_w"], p["conv_b"], cache=conv_cache
    )
    di, n = cfg.d_inner, cfg.ssm_state
    x = conv_out[..., :di]
    bmat = conv_out[..., di : di + n].astype(jnp.float32)
    cmat = conv_out[..., di + n :].astype(jnp.float32)
    nh, pd = cfg.ssm_nheads, cfg.ssm_headdim
    xh = x.reshape(bsz, s, nh, pd).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    log_decay = dtf * a
    xdt = xh * dtf[..., None]
    y, state = ssd_chunked(
        xdt, log_decay, bmat, cmat, cfg.ssm_chunk,
        h0=state0.astype(jnp.float32), unroll=cfg.cost_exact,
    )
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    return out, new_tail, state


def mamba_decode_step(
    p: dict,
    u: jax.Array,        # (B,1,D)
    conv_cache: jax.Array,  # (B,K-1,C)
    state: jax.Array,       # (B,H,P,N) f32
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent update (O(1) in sequence length)."""
    bsz = u.shape[0]
    zxbcdt = u[:, 0, :] @ p["in_proj"].astype(u.dtype)  # (B, proj)
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)  # (B, C)
    window = jnp.concatenate([conv_cache, conv_in[:, None, :]], axis=1)
    w = p["conv_w"]  # (K, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )
    # round through the activation dtype to match the prefill path exactly
    conv_out = conv_out.astype(u.dtype).astype(jnp.float32)
    new_conv_cache = window[:, 1:, :]
    di, n = cfg.d_inner, cfg.ssm_state
    x = conv_out[:, :di]
    bmat = conv_out[:, di : di + n]
    cmat = conv_out[:, di + n :]
    nh, pd = cfg.ssm_nheads, cfg.ssm_headdim
    xh = x.reshape(bsz, nh, pd)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dtf * a)  # (B,H)
    xdt = xh * dtf[..., None]
    state = state * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, bmat
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cmat)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    return out[:, None, :], new_conv_cache, state
