"""Decoder-style LM stack covering decoder_lm / moe_lm / ssm / hybrid / vlm
families, with scan-over-units + remat (compile-time and memory bounded),
prefill/decode paths, and vocab-sharded cross-entropy.

The repeating "unit" is cfg.layer_pattern (e.g. gemma2 ("local","global"),
recurrentgemma ("rec","rec","attn"), mamba2 ("ssm",)); units are identical
pytrees so the whole depth is a single lax.scan over stacked params — the
HLO holds ONE unit body regardless of depth, which keeps 512-device GSPMD
compiles tractable and is itself a production requirement (MaxText-style).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import parallel
from . import attention as ATT
from . import mamba2 as M2
from . import moe as MOE
from . import rglru as RG
from .config import ModelConfig
from .layers import (
    dtype_of,
    embed,
    init_embedding,
    init_lm_head,
    init_mlp,
    init_rmsnorm,
    logits_out,
    mlp_apply,
    rmsnorm,
)

# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def _init_sublayer(key, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    if kind in ("global", "local"):
        p = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": ATT.init_attn(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
        }
        if cfg.n_experts > 0:
            p["moe"] = MOE.init_moe(ks[1], cfg)
        else:
            p["ffn"] = init_mlp(ks[1], cfg)
        if cfg.post_norms:
            p["post_ln1"] = init_rmsnorm(cfg.d_model)
            p["post_ln2"] = init_rmsnorm(cfg.d_model)
        return p
    if kind == "rec":
        return {
            "ln1": init_rmsnorm(cfg.d_model),
            "rec": RG.init_rglru_block(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model),
            "ffn": init_mlp(ks[1], cfg),
        }
    if kind == "ssm":
        return {
            "ln1": init_rmsnorm(cfg.d_model),
            "mixer": M2.init_mamba(ks[0], cfg),
        }
    raise ValueError(kind)


def init_unit(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, len(cfg.layer_pattern))
    return {
        f"l{i}": _init_sublayer(ks[i], kind, cfg)
        for i, kind in enumerate(cfg.layer_pattern)
    }


def init_lm(key, cfg: ModelConfig) -> dict:
    ke, kh, ku = jax.random.split(key, 3)
    params: dict = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model, dtype_of(cfg)),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = init_lm_head(kh, cfg.d_model, cfg.vocab, dtype_of(cfg))
    unit_keys = jax.random.split(ku, cfg.n_units)
    params["units"] = jax.vmap(lambda k: init_unit(k, cfg))(unit_keys)
    return params


# ---------------------------------------------------------------------------
# Forward (full-sequence).
# ---------------------------------------------------------------------------


def _unit_fwd(
    x: jax.Array,
    up: dict,
    positions: jax.Array,
    cfg: ModelConfig,
    key: Optional[jax.Array],
) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.layer_pattern):
        sub = up[f"l{i}"]
        ki = None if key is None else jax.random.fold_in(key, i)
        if kind in ("global", "local"):
            a = ATT.self_attention(
                sub["attn"],
                rmsnorm(sub["ln1"], x, cfg.norm_eps),
                positions,
                cfg,
                kind=kind,
                key=None if ki is None else jax.random.fold_in(ki, 0),
            )
            if cfg.post_norms:
                a = rmsnorm(sub["post_ln1"], a, cfg.norm_eps)
            x = x + a
            h = rmsnorm(sub["ln2"], x, cfg.norm_eps)
            if cfg.n_experts > 0:
                f, aux_i = MOE.moe_apply(
                    sub["moe"], h, cfg,
                    key=None if ki is None else jax.random.fold_in(ki, 1),
                )
                aux = aux + aux_i
            else:
                f = mlp_apply(
                    sub["ffn"], h, cfg,
                    key=None if ki is None else jax.random.fold_in(ki, 1),
                )
            if cfg.post_norms:
                f = rmsnorm(sub["post_ln2"], f, cfg.norm_eps)
            x = x + f
        elif kind == "rec":
            x = x + RG.rglru_block_apply(
                sub["rec"], rmsnorm(sub["ln1"], x, cfg.norm_eps), cfg, ki
            )
            x = x + mlp_apply(
                sub["ffn"], rmsnorm(sub["ln2"], x, cfg.norm_eps), cfg,
                None if ki is None else jax.random.fold_in(ki, 1),
            )
        elif kind == "ssm":
            x = x + M2.mamba_apply(
                sub["mixer"], rmsnorm(sub["ln1"], x, cfg.norm_eps), cfg, ki
            )
        x = parallel.shard(x, ("batch", "seq", "embed"))
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


def backbone(
    params: dict,
    x: jax.Array,           # (B,S,D) already embedded
    positions: jax.Array,   # (B,S)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Run all units; returns (hidden states, aux loss)."""

    def body(carry, xs):
        h, aux = carry
        up, uidx = xs
        ku = None if key is None else jax.random.fold_in(key, uidx)
        h, aux_u = _unit_fwd(h, up, positions, cfg, ku)
        return (h, aux + aux_u), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(
            body,
            (x, jnp.zeros((), jnp.float32)),
            (params["units"], jnp.arange(cfg.n_units)),
            unroll=True if cfg.cost_exact else cfg.scan_unroll,
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        for u in range(cfg.n_units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            (x, aux), _ = body((x, aux), (up, jnp.asarray(u)))
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def lm_forward(
    params: dict,
    tokens: jax.Array,  # (B,S)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
    prefix_embeds: Optional[jax.Array] = None,  # (B,P,D) VLM patch embeds
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S',V), aux); S' = P + S with a VLM prefix."""
    x = embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, aux = backbone(params, x, positions, cfg, key)
    logits = logits_out(params["embed"], params.get("head"), x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# Loss (vocab-sharded cross-entropy with distributed LSE).
# ---------------------------------------------------------------------------


def cross_entropy(
    logits: jax.Array,  # (B,S,V) — V may be model-sharded
    labels: jax.Array,  # (B,S) int32
    mask: Optional[jax.Array] = None,
    z_loss: float = 1e-4,
) -> tuple[jax.Array, dict]:
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    sumexp = jnp.sum(jnp.exp(lf - m), axis=-1)
    lse = m[..., 0] + jnp.log(sumexp)
    # label logit via masked reduction — no gather across the sharded vocab
    v = lf.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is not None:
        w = mask.astype(jnp.float32)
        loss = jnp.sum(per_tok * w) / jnp.maximum(w.sum(), 1.0)
    else:
        loss = per_tok.mean()
    return loss, {"nll": loss, "lse_mean": lse.mean()}


def lm_loss(
    params: dict,
    batch: dict,  # {"tokens": (B,S), "labels": (B,S), optional "mask", "patches"}
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    logits, aux = lm_forward(
        params, batch["tokens"], cfg, key, batch.get("patches")
    )
    labels = batch["labels"]
    if batch.get("patches") is not None:
        logits = logits[:, -labels.shape[1] :, :]  # loss on text positions
    loss, metrics = cross_entropy(logits, labels, batch.get("mask"))
    total = loss + aux
    metrics["aux"] = aux
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Prefill / decode.
# ---------------------------------------------------------------------------


def _state_cache_leaves(cfg: ModelConfig, batch: int) -> dict:
    """Recurrent/SSM per-slot states — O(1) per token, so they stay dense
    (slot-addressable) in both the dense and the paged cache layouts."""
    dt = dtype_of(cfg)
    nu = cfg.n_units
    pat = cfg.layer_pattern
    cache: dict = {}
    n_rec = sum(1 for k in pat if k == "rec")
    if n_rec:
        w = cfg.lru_width or cfg.d_model
        cache["rec_conv"] = jnp.zeros((nu, n_rec, batch, 3, w), dt)
        cache["rec_h"] = jnp.zeros((nu, n_rec, batch, w), jnp.float32)
    n_ssm = sum(1 for k in pat if k == "ssm")
    if n_ssm:
        ch = cfg.d_inner + 2 * cfg.ssm_state
        cache["ssm_conv"] = jnp.zeros(
            (nu, n_ssm, batch, cfg.ssm_conv - 1, ch), dt
        )
        cache["ssm_state"] = jnp.zeros(
            (nu, n_ssm, batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32,
        )
    return cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Family-appropriate cache pytree with a leading n_units axis."""
    dt = dtype_of(cfg)
    nu = cfg.n_units
    cache: dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    pat = cfg.layer_pattern
    n_attn = sum(1 for k in pat if k in ("global", "local"))
    if n_attn:
        shape = (nu, n_attn, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            cache["k"] = jnp.zeros(shape, jnp.int8)
            cache["v"] = jnp.zeros(shape, jnp.int8)
            cache["k_scale"] = jnp.ones(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.ones(shape[:-1], jnp.float32)
        else:
            cache["k"] = jnp.zeros(shape, dt)
            cache["v"] = jnp.zeros(shape, dt)
    cache.update(_state_cache_leaves(cfg, batch))
    return cache


def init_paged_decode_cache(
    cfg: ModelConfig, batch: int, n_pages: int, block_size: int
) -> dict:
    """Paged-layout cache: a shared pool of fixed-size KV blocks.

    Attention K/V live in (nu, n_attn, n_pages, block_size, Hkv, Dh) pools
    shared by ALL slots; which pages a slot owns is the engine's block
    table (host state, passed to the decode step each tick).  Capacity is
    pooled: n_pages · block_size tokens total, instead of the dense
    batch · max_len per-slot reservation.  A page may even back SEVERAL
    slots' tables at once (prefix sharing): prompt blocks are read-only
    for their whole shared lifetime, and the engine copy-on-write forks a
    shared page before any slot writes into it, so nothing in this layout
    (or the decode step) distinguishes shared from private pages.
    Recurrent/SSM states keep the dense slot layout (they are O(1) per
    slot and never shared — they are inserted per admission, from the
    prefill or from the prefix index's stored payload).

    With ``cfg.kv_cache_dtype == "int8"`` the K/V pools hold int8 codes
    (half the HBM bytes per page) plus per-(page, slot-in-page, head) f32
    scale planes; writes quantize with unbiased stochastic rounding and
    reads fold the scales into the attention math (see
    attention.paged_decode_self_attention).
    """
    dt = dtype_of(cfg)
    nu = cfg.n_units
    cache: dict = {"pos": jnp.zeros((batch,), jnp.int32)}
    pat = cfg.layer_pattern
    n_attn = sum(1 for k in pat if k in ("global", "local"))
    if n_attn:
        shape = (
            nu, n_attn, n_pages, block_size, cfg.n_kv_heads, cfg.head_dim
        )
        if cfg.kv_cache_dtype == "int8":
            cache["k_pages"] = jnp.zeros(shape, jnp.int8)
            cache["v_pages"] = jnp.zeros(shape, jnp.int8)
            cache["k_scale_pages"] = jnp.ones(shape[:-1], jnp.float32)
            cache["v_scale_pages"] = jnp.ones(shape[:-1], jnp.float32)
            # monotonic decode-step counter seeding the stochastic rounding:
            # +1 per lm_decode_step, never reset by inserts/eviction, so a
            # cache write's rounding draw is never replayed over the
            # engine's lifetime (a pos-derived seed would repeat after slot
            # turnover)
            cache["quant_step"] = jnp.zeros((), jnp.int32)
        else:
            cache["k_pages"] = jnp.zeros(shape, dt)
            cache["v_pages"] = jnp.zeros(shape, dt)
    cache.update(_state_cache_leaves(cfg, batch))
    return cache


# shared-pool cache leaves (block-table addressed); everything else in a
# paged cache is dense per-slot state.  launch.specs re-exports this as
# PAGE_POOL_LEAVES — keep the two in sync.
PAGE_POOL_LEAVES = (
    "k_pages", "v_pages", "k_scale_pages", "v_scale_pages"
)


def _unit_decode(
    x: jax.Array,         # (B,1,D)
    up: dict,
    ucache: dict,
    pos: jax.Array,       # (B,)
    cfg: ModelConfig,
    table: Optional[jax.Array] = None,  # (B, W) block table (paged cache)
    uidx: jax.Array | int = 0,          # unit index (seeds int8 rounding)
    quant_base: Optional[jax.Array] = None,  # engine-wide decode counter
    kv_write: bool = True,
) -> tuple[jax.Array, dict]:
    # kv_write=False is the speculative-verify cell: identical math, but
    # the K/V pool is read-only (the draft steps already wrote these
    # positions) and the pool leaves are dropped from the returned cache
    # so a scan over verify cells never carries or re-stacks the pool.
    paged = "k_pages" in ucache
    int8_pool = "k_scale_pages" in ucache
    if kv_write:
        new_cache = dict(ucache)
    else:
        new_cache = {
            k: v for k, v in ucache.items() if k not in PAGE_POOL_LEAVES
        }
    i_attn = i_rec = i_ssm = 0
    for i, kind in enumerate(cfg.layer_pattern):
        sub = up[f"l{i}"]
        if kind in ("global", "local"):
            # attention + cache write is the only paged/dense divergence;
            # the norm/FFN tail below is shared so the layouts cannot drift
            if paged:
                scale_kw = {}
                if not kv_write and int8_pool:
                    scale_kw = dict(
                        k_scale_pages=ucache["k_scale_pages"][i_attn],
                        v_scale_pages=ucache["v_scale_pages"][i_attn],
                    )
                elif int8_pool:
                    # per-(decode step, unit, sublayer) counter-PRNG seed:
                    # quant_base ticks monotonically per lm_decode_step, so
                    # every cache write draws fresh unbiased rounding noise
                    # over the engine's lifetime; the per-element counter
                    # inside stoch_round decorrelates slots/heads within
                    # one write
                    seed = (
                        quant_base.astype(jnp.uint32)
                        * jnp.uint32(2654435761)
                        + jnp.asarray(uidx).astype(jnp.uint32)
                        * jnp.uint32(40503)
                        + jnp.uint32(i * 1299721)
                    )
                    scale_kw = dict(
                        k_scale_pages=ucache["k_scale_pages"][i_attn],
                        v_scale_pages=ucache["v_scale_pages"][i_attn],
                        quant_seed=seed,
                    )
                res = ATT.paged_decode_self_attention(
                    sub["attn"],
                    rmsnorm(sub["ln1"], x, cfg.norm_eps),
                    ucache["k_pages"][i_attn],
                    ucache["v_pages"][i_attn],
                    table,
                    pos,
                    cfg,
                    kind=kind,
                    write=kv_write,
                    **scale_kw,
                )
                a, kp, vp = res[:3]
                if kv_write:
                    new_cache["k_pages"] = (
                        new_cache["k_pages"].at[i_attn].set(kp)
                    )
                    new_cache["v_pages"] = (
                        new_cache["v_pages"].at[i_attn].set(vp)
                    )
                    if int8_pool:
                        new_cache["k_scale_pages"] = (
                            new_cache["k_scale_pages"].at[i_attn].set(res[3])
                        )
                        new_cache["v_scale_pages"] = (
                            new_cache["v_scale_pages"].at[i_attn].set(res[4])
                        )
            else:
                int8 = cfg.kv_cache_dtype == "int8"
                res = ATT.decode_self_attention(
                    sub["attn"],
                    rmsnorm(sub["ln1"], x, cfg.norm_eps),
                    ucache["k"][i_attn],
                    ucache["v"][i_attn],
                    pos,
                    cfg,
                    kind=kind,
                    k_scale=ucache["k_scale"][i_attn] if int8 else None,
                    v_scale=ucache["v_scale"][i_attn] if int8 else None,
                )
                a, kc, vc = res[:3]
                new_cache["k"] = new_cache["k"].at[i_attn].set(kc)
                new_cache["v"] = new_cache["v"].at[i_attn].set(vc)
                if int8:
                    new_cache["k_scale"] = (
                        new_cache["k_scale"].at[i_attn].set(res[3])
                    )
                    new_cache["v_scale"] = (
                        new_cache["v_scale"].at[i_attn].set(res[4])
                    )
            i_attn += 1
            if cfg.post_norms:
                a = rmsnorm(sub["post_ln1"], a, cfg.norm_eps)
            x = x + a
            h = rmsnorm(sub["ln2"], x, cfg.norm_eps)
            if cfg.n_experts > 0:
                f, _ = MOE.moe_apply(sub["moe"], h, cfg, None)
            else:
                f = mlp_apply(sub["ffn"], h, cfg, None)
            if cfg.post_norms:
                f = rmsnorm(sub["post_ln2"], f, cfg.norm_eps)
            x = x + f
        elif kind == "rec":
            o, conv, hst = RG.rglru_decode_step(
                sub["rec"],
                rmsnorm(sub["ln1"], x, cfg.norm_eps),
                ucache["rec_conv"][i_rec],
                ucache["rec_h"][i_rec],
                cfg,
            )
            new_cache["rec_conv"] = new_cache["rec_conv"].at[i_rec].set(conv)
            new_cache["rec_h"] = new_cache["rec_h"].at[i_rec].set(hst)
            i_rec += 1
            x = x + o
            x = x + mlp_apply(
                sub["ffn"], rmsnorm(sub["ln2"], x, cfg.norm_eps), cfg, None
            )
        elif kind == "ssm":
            o, conv, st = M2.mamba_decode_step(
                sub["mixer"],
                rmsnorm(sub["ln1"], x, cfg.norm_eps),
                ucache["ssm_conv"][i_ssm],
                ucache["ssm_state"][i_ssm],
                cfg,
            )
            new_cache["ssm_conv"] = new_cache["ssm_conv"].at[i_ssm].set(conv)
            new_cache["ssm_state"] = new_cache["ssm_state"].at[i_ssm].set(st)
            i_ssm += 1
            x = x + o
    return x, new_cache


def lm_decode_step(
    params: dict,
    cache: dict,
    token: jax.Array,  # (B,) int32 — last emitted token
    cfg: ModelConfig,
    table: Optional[jax.Array] = None,  # (B, W) block table (paged cache)
    kv_write: bool = True,
) -> tuple[dict, jax.Array]:
    """One decode step; returns (new cache, logits (B,V)).

    With a paged cache (``k_pages`` leaves + a block ``table``) attention
    reads/writes go through the block pool; the recurrence is otherwise
    identical to the dense path.

    ``kv_write=False`` is the speculative-verify mode: byte-for-byte the
    same math, but attention treats the pool as read-only (the draft
    already wrote these rows) and the returned cache carries only the
    dense per-slot leaves — no pool leaves, no ``quant_step`` tick."""
    pos = cache["pos"]
    qstep = cache.get("quant_step")  # int8 paged pools only
    x = embed(params["embed"], token[:, None], cfg)

    def body(carry, xs):
        h = carry
        up, uc, uidx = xs
        h, uc_new = _unit_decode(
            h, up, uc, pos, cfg, table, uidx, qstep, kv_write
        )
        return h, uc_new

    layer_cache = {
        k: v for k, v in cache.items() if k not in ("pos", "quant_step")
    }
    if cfg.scan_layers:
        x, new_layer_cache = jax.lax.scan(
            body, x, (params["units"], layer_cache, jnp.arange(cfg.n_units)),
            unroll=True if cfg.cost_exact else 1,
        )
    else:
        ys = []
        for u in range(cfg.n_units):
            up = jax.tree.map(lambda a: a[u], params["units"])
            uc = jax.tree.map(lambda a: a[u], layer_cache)
            x, uc_new = body(x, (up, uc, u))
            ys.append(uc_new)
        new_layer_cache = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], params.get("head"), x, cfg)
    new_cache = dict(new_layer_cache)
    new_cache["pos"] = pos + 1
    if qstep is not None and kv_write:
        new_cache["quant_step"] = qstep + 1
    return new_cache, logits[:, 0, :]


def init_prefill_state(cfg: ModelConfig) -> dict:
    """Zeroed B=1 per-slot state leaves entering a chunked prefill.

    ``pos`` plus the recurrent/SSM leaves — exactly the leaves
    :func:`lm_prefill_chunk` threads between chunks and the engine's
    state insert writes at the slot on completion."""
    state = {"pos": jnp.zeros((1,), jnp.int32)}
    state.update(_state_cache_leaves(cfg, 1))
    return state


def lm_prefill_chunk(
    params: dict,
    tokens: jax.Array,     # (1, c) — one request's suffix chunk
    cfg: ModelConfig,
    pool: dict,            # page-pool leaves (k_pages/v_pages[/scales])
    state: dict,           # B=1 per-slot leaves incl. "pos" (see above)
    table_row: jax.Array,  # (Wp,) int32 blocks covering the prompt bucket
    q0: jax.Array,         # () int32 absolute position of the chunk start
    bucket: int,           # static padded prompt length
    quant_seeds: Optional[jax.Array] = None,  # (nbc,) uint32, int8 pools
    all_logits: bool = False,
) -> tuple[dict, dict, jax.Array]:
    """One chunk of a resumable paged prefill.

    The chunked analogue of :func:`lm_prefill` for the paged layout:
    attention layers write the chunk's K/V into the request's own pages
    and attend over the whole prompt window (shared prefix pages
    included) at absolute positions; recurrent/SSM layers advance their
    state from the carried ``state`` leaves.  A single chunk covering the
    whole bucket from zeroed state reproduces the monolithic prefill
    bit-for-bit — the equivalence anchor for the dense-vs-paged and
    sharing-on-vs-off byte-identity contracts.  int8 pools quantize each
    chunk block under its content-derived seed (folded with the unit and
    sublayer index), so shared blocks stay bit-identical across writers.

    Returns (pool', state', last-token logits (1, V)); ``state'`` is the
    boundary snapshot the engine stashes in the prefix index so a later
    partial-prefix hit can resume exactly here.  With ``all_logits`` the
    logits output is (1, c, V) — every chunk row, not just the last: the
    multi-token-logits variant that lets a k-token chunk act as a
    one-call verifier/oracle over k decode positions (row ``i`` is the
    next-token distribution after absolute position ``q0 + i``).
    """
    b, c = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(q0 + jnp.arange(c)[None], (b, c))
    int8_pool = "k_scale_pages" in pool
    layer_state = {k: v for k, v in state.items() if k != "pos"}

    def body(carry, xs):
        h = carry
        up, uc, us, uidx = xs
        new_uc = dict(uc)
        new_us = dict(us)
        ia = ir = ism = 0
        for i, kind in enumerate(cfg.layer_pattern):
            sub = up[f"l{i}"]
            if kind in ("global", "local"):
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                kw = {}
                if int8_pool:
                    # content seed folded with (unit, sublayer): rounding
                    # draws decorrelate across layers while staying a pure
                    # function of (block content, block position, layer) —
                    # the property that keeps int8 blocks shareable
                    kw = dict(
                        k_scale_pages=uc["k_scale_pages"][ia],
                        v_scale_pages=uc["v_scale_pages"][ia],
                        quant_seeds=(
                            quant_seeds
                            + jnp.asarray(uidx).astype(jnp.uint32)
                            * jnp.uint32(40503)
                            + jnp.uint32(i * 1299721)
                        ),
                    )
                res = ATT.paged_prefill_self_attention(
                    sub["attn"], hin,
                    uc["k_pages"][ia], uc["v_pages"][ia],
                    table_row, q0, bucket, cfg, kind=kind, **kw,
                )
                o, kp, vp = res[:3]
                new_uc["k_pages"] = new_uc["k_pages"].at[ia].set(kp)
                new_uc["v_pages"] = new_uc["v_pages"].at[ia].set(vp)
                if int8_pool:
                    new_uc["k_scale_pages"] = (
                        new_uc["k_scale_pages"].at[ia].set(res[3])
                    )
                    new_uc["v_scale_pages"] = (
                        new_uc["v_scale_pages"].at[ia].set(res[4])
                    )
                if cfg.post_norms:
                    o = rmsnorm(sub["post_ln1"], o, cfg.norm_eps)
                h = h + o
                hm = rmsnorm(sub["ln2"], h, cfg.norm_eps)
                if cfg.n_experts > 0:
                    f, _ = MOE.moe_apply(sub["moe"], hm, cfg, None)
                else:
                    f = mlp_apply(sub["ffn"], hm, cfg, None)
                if cfg.post_norms:
                    f = rmsnorm(sub["post_ln2"], f, cfg.norm_eps)
                h = h + f
                ia += 1
            elif kind == "rec":
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                o, conv, hl = RG.rglru_prefill_chunk(
                    sub["rec"], hin,
                    us["rec_conv"][ir], us["rec_h"][ir], cfg,
                )
                new_us["rec_conv"] = new_us["rec_conv"].at[ir].set(conv)
                new_us["rec_h"] = new_us["rec_h"].at[ir].set(hl)
                h = h + o
                h = h + mlp_apply(
                    sub["ffn"], rmsnorm(sub["ln2"], h, cfg.norm_eps),
                    cfg, None,
                )
                ir += 1
            elif kind == "ssm":
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                o, conv, st = M2.mamba_prefill_chunk(
                    sub["mixer"], hin,
                    us["ssm_conv"][ism], us["ssm_state"][ism], cfg,
                )
                new_us["ssm_conv"] = new_us["ssm_conv"].at[ism].set(conv)
                new_us["ssm_state"] = new_us["ssm_state"].at[ism].set(st)
                h = h + o
                ism += 1
        return h, (new_uc, new_us)

    # always scan over units — :func:`lm_prefill` scans unconditionally
    # (unlike the decode step, which branches on ``scan_layers``), and the
    # bit-identity anchor requires the exact same HLO structure
    x, (new_pool, new_layer_state) = jax.lax.scan(
        body, x,
        (params["units"], pool, layer_state, jnp.arange(cfg.n_units)),
        unroll=True if cfg.cost_exact else 1,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    rows = x if all_logits else x[:, -1:, :]
    logits = logits_out(params["embed"], params.get("head"), rows, cfg)
    new_state = dict(new_layer_state)
    new_state["pos"] = jnp.full((b,), q0 + c, jnp.int32)
    return new_pool, new_state, logits if all_logits else logits[:, 0, :]


def lm_prefill(
    params: dict,
    tokens: jax.Array,  # (B,S)
    cfg: ModelConfig,
    max_len: int,
    prefix_embeds: Optional[jax.Array] = None,
) -> tuple[dict, jax.Array]:
    """Run the full prompt, building a decode cache.  For attention layers
    this recomputes K/V into the cache buffer; recurrent/SSM layers keep
    their O(1) states.  Returns (cache, last-token logits)."""
    b, s = tokens.shape[0], tokens.shape[1]
    if prefix_embeds is not None:
        s = s + prefix_embeds.shape[1]
    cache = init_decode_cache(cfg, b, max_len)
    x = embed(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    n_attn = sum(1 for k in cfg.layer_pattern if k in ("global", "local"))
    n_rec = sum(1 for k in cfg.layer_pattern if k == "rec")
    n_ssm = sum(1 for k in cfg.layer_pattern if k == "ssm")

    def body(carry, xs):
        h = carry
        up, uidx = xs
        outs: dict = {}
        ia = ir = ism = 0
        for i, kind in enumerate(cfg.layer_pattern):
            sub = up[f"l{i}"]
            if kind in ("global", "local"):
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                q, k, v = ATT.qkv(sub["attn"], hin, cfg, None)
                from .layers import apply_rope

                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
                o = ATT.attend_full(
                    q, k, v, positions[0], positions[0], kind, cfg
                )
                o = o.reshape(b, s, -1) @ sub["attn"]["wo"].astype(h.dtype)
                if cfg.post_norms:
                    o = rmsnorm(sub["post_ln1"], o, cfg.norm_eps)
                h = h + o
                hm = rmsnorm(sub["ln2"], h, cfg.norm_eps)
                if cfg.n_experts > 0:
                    f, _ = MOE.moe_apply(sub["moe"], hm, cfg, None)
                else:
                    f = mlp_apply(sub["ffn"], hm, cfg, None)
                if cfg.post_norms:
                    f = rmsnorm(sub["post_ln2"], f, cfg.norm_eps)
                h = h + f
                pad = max_len - s
                kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                if cfg.kv_cache_dtype == "int8":
                    k8, ks = ATT.quantize_kv(kp)
                    v8, vs = ATT.quantize_kv(vp)
                    outs.setdefault("k", []).append(k8)
                    outs.setdefault("v", []).append(v8)
                    outs.setdefault("k_scale", []).append(ks)
                    outs.setdefault("v_scale", []).append(vs)
                else:
                    outs.setdefault("k", []).append(kp)
                    outs.setdefault("v", []).append(vp)
                ia += 1
            elif kind == "rec":
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                o, conv_tail, h_last = RG.rglru_prefill(sub["rec"], hin, cfg)
                h = h + o
                h = h + mlp_apply(
                    sub["ffn"], rmsnorm(sub["ln2"], h, cfg.norm_eps), cfg, None
                )
                outs.setdefault("rec_conv", []).append(conv_tail)
                outs.setdefault("rec_h", []).append(h_last)
                ir += 1
            elif kind == "ssm":
                hin = rmsnorm(sub["ln1"], h, cfg.norm_eps)
                o, conv_tail, st = M2.mamba_prefill(sub["mixer"], hin, cfg)
                h = h + o
                outs.setdefault("ssm_conv", []).append(conv_tail)
                outs.setdefault("ssm_state", []).append(st)
                ism += 1
        outs = {k2: jnp.stack(v2) for k2, v2 in outs.items()}
        return h, outs

    x, per_unit = jax.lax.scan(
        body, x, (params["units"], jnp.arange(cfg.n_units)),
        unroll=True if cfg.cost_exact else 1,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_out(params["embed"], params.get("head"), x[:, -1:, :], cfg)
    for k2, v2 in per_unit.items():
        cache[k2] = v2.astype(cache[k2].dtype)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return cache, logits[:, 0, :]
