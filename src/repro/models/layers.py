"""Shared neural-net layers: norms, embeddings, RoPE, MLP variants.

Pure functional style: ``init_*`` builds param dicts, ``apply``-style
functions consume them.  Every matmul routes through core.analog so any
layer can execute in RACA analog mode (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import parallel
from repro.core import analog as A
from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    return (xf * (1.0 + p["scale"])).astype(dt)


# ---------------------------------------------------------------------------
# Embeddings + logits.
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    emb = jax.random.normal(key, (vocab, d), jnp.float32) * (d**-0.5)
    return {"embedding": emb.astype(dtype)}


def embed(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return parallel.shard(x, ("batch", "seq", "embed"))


def logits_out(
    p_emb: dict,
    p_head: Optional[dict],
    x: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    """Final logits; vocab axis is model-sharded (distributed LSE CE)."""
    w = p_emb["embedding"].T if p_head is None else p_head["w"]
    logits = x @ w.astype(x.dtype)
    if cfg.logit_softcap > 0.0:
        c = jnp.asarray(cfg.logit_softcap, logits.dtype)
        logits = c * jnp.tanh(logits / c)
    return parallel.shard(logits, ("batch", "seq", "vocab"))


def init_lm_head(key, d: int, vocab: int, dtype) -> dict:
    w = jax.random.normal(key, (d, vocab), jnp.float32) * (d**-0.5)
    return {"w": w.astype(dtype)}


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants (all routed through core.analog).
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": (jax.random.normal(k1, (d, f), jnp.float32) * d**-0.5).astype(dt),
        "w_down": (jax.random.normal(k2, (f, d), jnp.float32) * f**-0.5).astype(dt),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = (
            jax.random.normal(k3, (d, f), jnp.float32) * d**-0.5
        ).astype(dt)
    return p


def mlp_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """MLP with optional RACA analog execution.

    ``analog_stochastic`` realizes the paper's binary stochastic Sigmoid
    neuron as the hidden activation: the up-projection crossbar's comparator
    bank emits b_up ~ Bern(sigmoid(z_up)) (Eq. 8/13).  Gated variants drive a
    second comparator bank from the gate crossbar; the gating is then a
    binary AND (b_up·b_gate) — binary×binary, free in hardware, keeping the
    hidden layer fully DAC/ADC-free exactly as in the paper's hidden layers.
    The down-projection feeds the (digital) residual stream, so it runs as
    an analog crossbar with linear readout — the one conversion point the
    technique cannot remove in residual architectures (DESIGN.md §5).

    ``analog_linear`` keeps standard activations but adds crossbar
    quantization + thermal noise to every matmul (noise-aware training for
    non-sigmoidal archs, e.g. nemotron's squared-ReLU).
    """
    acfg = cfg.analog
    k1 = k2 = None
    if key is not None and acfg.mode != "digital":
        k1, k2 = jax.random.split(key)
    up = A.analog_matmul(acfg, k1, x, p["w_up"])
    up = parallel.shard(up, ("batch", "seq", "ffn"))

    if cfg.mlp == "swiglu":
        act = jax.nn.silu
    elif cfg.mlp == "relu2":
        act = lambda v: jnp.square(jax.nn.relu(v))
    else:  # geglu / gelu
        act = lambda v: jax.nn.gelu(v, approximate=True)

    if acfg.mode == "analog_stochastic":
        h = up  # already binary: the comparator IS the activation
        if "w_gate" in p:
            b_gate = A.analog_matmul(acfg, k2, x, p["w_gate"])
            h = h * parallel.shard(b_gate, ("batch", "seq", "ffn"))
    else:
        if "w_gate" in p:
            gate = A.analog_matmul(acfg, k2, x, p["w_gate"])
            gate = parallel.shard(gate, ("batch", "seq", "ffn"))
            h = act(gate) * up
        else:
            h = act(up)

    down_cfg = (
        acfg.with_mode("analog_linear")
        if acfg.mode == "analog_stochastic"
        else acfg
    )
    k3 = None if k2 is None else jax.random.fold_in(k2, 7)
    out = A.analog_matmul(down_cfg, k3, h, p["w_down"])
    return parallel.shard(out, ("batch", "seq", "embed"))


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    c = jnp.asarray(cap, x.dtype)
    return c * jnp.tanh(x / c)
