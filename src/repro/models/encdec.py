"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv-mel audio frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings (B, S_enc, D).  Everything downstream —
sinusoidal encoder positions, bidirectional encoder, causal decoder with
cross-attention, learned decoder positions, tied output head — is real.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import parallel
from . import attention as ATT
from .config import ModelConfig
from .layers import (
    dtype_of,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp_apply,
    rmsnorm,
)
from .transformer import cross_entropy


def sinusoid_pos(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": ATT.init_attn(k1, cfg),
        "ln2": init_rmsnorm(cfg.d_model),
        "ffn": init_mlp(k2, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model),
        "self_attn": ATT.init_attn(k1, cfg),
        "ln_x": init_rmsnorm(cfg.d_model),
        "cross_attn": ATT.init_attn(k2, cfg),
        "ln2": init_rmsnorm(cfg.d_model),
        "ffn": init_mlp(k3, cfg),
    }


def init_encdec(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg)
    return {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model, dt),
        "dec_pos": (
            jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model), jnp.float32)
            * 0.01
        ).astype(dt),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ks[2], cfg.enc_layers)
        ),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(ks[3], cfg.dec_layers)
        ),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "dec_norm": init_rmsnorm(cfg.d_model),
    }


def encode(
    params: dict,
    frames: jax.Array,  # (B, S_enc, D) precomputed frame embeddings (stub)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    b, s, d = frames.shape
    x = frames.astype(dtype_of(cfg)) + sinusoid_pos(s, d).astype(
        dtype_of(cfg)
    )
    x = parallel.shard(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        lp, li = xs
        ki = None if key is None else jax.random.fold_in(key, li)
        a = ATT.self_attention(
            lp["attn"],
            rmsnorm(lp["ln1"], h, cfg.norm_eps),
            positions,
            cfg,
            kind="none",  # bidirectional
            key=ki,
            use_rope=False,
        )
        h = h + a
        h = h + mlp_apply(
            lp["ffn"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg, ki
        )
        return h, None

    x, _ = jax.lax.scan(
        jax.checkpoint(body), x, (params["enc"], jnp.arange(cfg.enc_layers)),
        unroll=True if cfg.cost_exact else 1,
    )
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(
    params: dict,
    tokens: jax.Array,   # (B, S_dec)
    enc_out: jax.Array,  # (B, S_enc, D)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    b, s = tokens.shape
    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    x = x + params["dec_pos"][:s][None]
    x = parallel.shard(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        lp, li = xs
        ki = None if key is None else jax.random.fold_in(key, li + 1000)
        h = h + ATT.self_attention(
            lp["self_attn"],
            rmsnorm(lp["ln1"], h, cfg.norm_eps),
            positions,
            cfg,
            kind="global",
            key=ki,
            use_rope=False,
        )
        h = h + ATT.cross_attention(
            lp["cross_attn"],
            rmsnorm(lp["ln_x"], h, cfg.norm_eps),
            enc_out,
            cfg,
            key=ki,
        )
        h = h + mlp_apply(
            lp["ffn"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg, ki
        )
        return h, None

    x, _ = jax.lax.scan(
        jax.checkpoint(body), x, (params["dec"], jnp.arange(cfg.dec_layers)),
        unroll=True if cfg.cost_exact else 1,
    )
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x @ params["embed"]["embedding"].T.astype(x.dtype)
    return parallel.shard(logits, ("batch", "seq", "vocab"))


def encdec_loss(
    params: dict,
    batch: dict,  # {"frames": (B,S_enc,D), "tokens": (B,S_dec), "labels": ...}
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    enc_out = encode(params, batch["frames"], cfg, key)
    logits = decode_train(params, batch["tokens"], enc_out, cfg, key)
    loss, metrics = cross_entropy(logits, batch["labels"], batch.get("mask"))
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode path.
# ---------------------------------------------------------------------------


def init_encdec_cache(
    cfg: ModelConfig, batch: int, max_len: int, enc_len: int
) -> dict:
    dt = dtype_of(cfg)
    l = cfg.dec_layers
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((l, batch, max_len, hkv, hd), dt),
        "v": jnp.zeros((l, batch, max_len, hkv, hd), dt),
        # cross-attention K/V precomputed once from encoder output
        "ck": jnp.zeros((l, batch, enc_len, hkv, hd), dt),
        "cv": jnp.zeros((l, batch, enc_len, hkv, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def encdec_prefill(
    params: dict,
    frames: jax.Array,
    tokens: jax.Array,
    cfg: ModelConfig,
    max_len: int,
) -> tuple[dict, jax.Array]:
    """Encode audio, precompute cross K/V, run decoder prompt."""
    b = tokens.shape[0]
    s = tokens.shape[1]
    enc_out = encode(params, frames, cfg)
    t = enc_out.shape[1]
    cache = init_encdec_cache(cfg, b, max_len, t)

    def cross_kv(lp):
        k = (enc_out @ lp["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.head_dim
        )
        v = (enc_out @ lp["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.head_dim
        )
        return k, v

    ck, cv = jax.vmap(cross_kv)(params["dec"])
    cache["ck"], cache["cv"] = ck, cv

    x = jnp.take(params["embed"]["embedding"], tokens, axis=0)
    x = x + params["dec_pos"][:s][None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, xs):
        lp, li = xs
        hin = rmsnorm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = ATT.qkv(lp["self_attn"], hin, cfg, None)
        o = ATT.attend_full(q, k, v, positions[0], positions[0], "global", cfg)
        h = h + o.reshape(b, s, -1) @ lp["self_attn"]["wo"].astype(h.dtype)
        h = h + ATT.cross_attention(
            lp["cross_attn"],
            rmsnorm(lp["ln_x"], h, cfg.norm_eps),
            enc_out,
            cfg,
        )
        h = h + mlp_apply(
            lp["ffn"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg, None
        )
        pad = max_len - s
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (kp, vp)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], jnp.arange(cfg.dec_layers)),
        unroll=True if cfg.cost_exact else 1,
    )
    cache["k"], cache["v"] = ks, vs
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x[:, -1:, :] @ params["embed"]["embedding"].T.astype(x.dtype)
    return cache, logits[:, 0, :]


def encdec_decode_step(
    params: dict,
    cache: dict,
    token: jax.Array,  # (B,)
    cfg: ModelConfig,
) -> tuple[dict, jax.Array]:
    b = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"]["embedding"], token[:, None], axis=0)
    x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None, :]

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        o, kc2, vc2 = ATT.decode_self_attention(
            lp["self_attn"],
            rmsnorm(lp["ln1"], h, cfg.norm_eps),
            kc,
            vc,
            pos,
            cfg,
            use_rope=False,
        )
        h = h + o
        # cross-attention against fixed encoder K/V
        hx = rmsnorm(lp["ln_x"], h, cfg.norm_eps)
        q = (hx @ lp["cross_attn"]["wq"].astype(hx.dtype)).reshape(
            b, 1, cfg.n_heads, cfg.head_dim
        )
        qg = q.reshape(
            b, 1, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, -1
        ).astype(jnp.float32) * (cfg.head_dim**-0.5)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, ck.astype(jnp.float32))
        w = jax.nn.softmax(sc, axis=-1)
        o2 = jnp.einsum("bkgst,btkd->bskgd", w, cv.astype(jnp.float32))
        o2 = o2.reshape(b, 1, -1).astype(h.dtype)
        h = h + o2 @ lp["cross_attn"]["wo"].astype(h.dtype)
        h = h + mlp_apply(
            lp["ffn"], rmsnorm(lp["ln2"], h, cfg.norm_eps), cfg, None
        )
        return h, (kc2, vc2)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec"], cache["k"], cache["v"], cache["ck"], cache["cv"]),
        unroll=True if cfg.cost_exact else 1,
    )
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ks, vs
    new_cache["pos"] = pos + 1
    x = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = x @ params["embed"]["embedding"].T.astype(x.dtype)
    return new_cache, logits[:, 0, :]
