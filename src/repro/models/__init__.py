"""Model zoo registry: family → (init / loss / prefill / decode) functions."""

from __future__ import annotations

from typing import Callable, NamedTuple

from . import encdec as ED
from . import fcnn as FC
from . import transformer as TF
from .config import ModelConfig


class ModelFns(NamedTuple):
    init: Callable          # (key, cfg) -> params
    loss: Callable          # (params, batch, cfg, key) -> (loss, metrics)
    prefill: Callable | None
    decode_step: Callable | None


def get_model_fns(cfg: ModelConfig) -> ModelFns:
    if cfg.family == "encdec":
        return ModelFns(
            init=ED.init_encdec,
            loss=ED.encdec_loss,
            prefill=lambda params, batch, cfg, max_len: ED.encdec_prefill(
                params, batch["frames"], batch["tokens"], cfg, max_len
            ),
            decode_step=ED.encdec_decode_step,
        )
    if cfg.family == "fcnn":
        return ModelFns(
            init=FC.init_fcnn, loss=FC.fcnn_loss, prefill=None, decode_step=None
        )
    # decoder_lm | moe_lm | ssm | hybrid | vlm
    return ModelFns(
        init=TF.init_lm,
        loss=TF.lm_loss,
        prefill=lambda params, batch, cfg, max_len: TF.lm_prefill(
            params, batch["tokens"], cfg, max_len, batch.get("patches")
        ),
        decode_step=TF.lm_decode_step,
    )


__all__ = ["ModelConfig", "ModelFns", "get_model_fns"]
