"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Dispatch is one-hot-free (MegaBlocks-style): per token group, tokens are
assigned slots in an (E, C) buffer via cumulative positions; experts run as
batched einsums over gathered tokens; outputs scatter-add back weighted by
the router gate.  Overflow beyond capacity C is dropped (standard GShard
semantics), underflow slots point at a zero pad row.

The router is a literal use case for the paper's WTA circuit: top-k expert
selection is a k-winner-take-all race (DESIGN.md §5).  With
``analog.mode == "analog_stochastic"`` routing uses core.wta.wta_topk —
vote counts over noisy comparator trials; digital mode uses exact top-k.

Aux load-balancing loss (Switch-style) is returned alongside the output.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import parallel
from repro.core import analog as A
from .config import ModelConfig
from .layers import dtype_of


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    init = lambda k, shape, fan: (
        jax.random.normal(k, shape, jnp.float32) * fan**-0.5
    ).astype(dt)
    p = {
        "router": init(ks[0], (d, e), d).astype(jnp.float32),
        "w_up": init(ks[1], (e, d, f), d),
        "w_down": init(ks[2], (e, f, d), f),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = init(ks[3], (e, d, f), d)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.moe_topk * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.moe_topk)


def _dispatch_group(xf, logits, gates, expert_ids, cap: int, cfg):
    """Slot assignment + gather for ONE token group (T, D).

    Groups are sequences (the batch dim), so the cumsum that assigns slot
    positions is LOCAL to a data shard — a global-token dispatch would force
    GSPMD to replicate expert compute across the data axis (16× waste; see
    EXPERIMENTS.md §Perf notes)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.moe_topk
    flat_e = expert_ids.reshape(-1)            # (T*k,)
    onehot_e = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot_e, axis=0) - onehot_e     # pre-count
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    kept = pos < cap
    dest_c = jnp.where(kept, pos, cap)         # overflow -> dump column
    tok_of_assign = jnp.repeat(jnp.arange(t), k)

    # (E, C+1) buffers; sentinel T points at the zero pad row of x.
    idx_buf = jnp.full((e, cap + 1), t, jnp.int32)
    idx_buf = idx_buf.at[flat_e, dest_c].set(tok_of_assign)
    gate_buf = jnp.zeros((e, cap + 1), jnp.float32)
    gate_buf = gate_buf.at[flat_e, dest_c].set(gates.reshape(-1))
    idx = idx_buf[:, :cap]                     # (E, C)
    gate_slot = gate_buf[:, :cap]
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xg = xpad[idx]                             # (E, C, D)
    frac = jnp.mean(
        (onehot_e.reshape(t, k, e).sum(1) > 0).astype(jnp.float32), axis=0
    )
    return xg, idx, gate_slot, frac


def _combine_group(out_e, idx, gate_slot, t: int):
    e, cap, d = out_e.shape
    out_flat = (out_e * gate_slot[..., None].astype(out_e.dtype)).reshape(
        e * cap, d
    )
    y = jnp.zeros((t + 1, d), out_e.dtype)
    y = y.at[idx.reshape(-1)].add(out_flat)
    return y[:t]


def moe_apply(
    p: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).  GShard-style grouped
    dispatch: each sequence is a group, capacity C = S·k·cf/E per group."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    cap = _capacity(s, cfg)

    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    if key is not None and cfg.analog.mode == "analog_stochastic":
        # k-winner WTA router: the paper's SoftMax neuron generalized.
        gates, expert_ids = A.wta_router_topk(cfg.analog, key, logits, k)
    else:
        gates, expert_ids = jax.lax.top_k(probs, k)  # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    dispatch = jax.vmap(
        lambda xf, lg, gt, ei: _dispatch_group(xf, lg, gt, ei, cap, cfg)
    )
    # The slot-assignment scatters defeat GSPMD's sharding propagation (it
    # replicates them — 100s of GB at grok scale), so when a mesh is active
    # the dispatch runs under shard_map over the batch axes: groups are
    # sequences, so per-shard dispatch is exact, not an approximation.
    ctx = parallel.current()
    bax = None
    if ctx is not None:
        mesh, rules = ctx
        bax = rules.get("batch")
    if bax:
        from jax.sharding import PartitionSpec as P

        bspec = P(bax)
        xg, idx, gate_slot, frac = jax.shard_map(
            dispatch,
            mesh=mesh,
            in_specs=(bspec, bspec, bspec, bspec),
            out_specs=(bspec, bspec, bspec, bspec),
        )(x, logits, gates, expert_ids)
    else:
        xg, idx, gate_slot, frac = dispatch(x, logits, gates, expert_ids)
    # xg: (B, E, C, D) — B over data, expert F dim over model.
    xg = parallel.shard(xg, ("batch", "experts", None, "embed"))

    up = jnp.einsum("becd,edf->becf", xg, p["w_up"].astype(xg.dtype))
    up = parallel.shard(up, ("batch", "experts", None, "ffn"))
    if "w_gate" in p:
        gt = jnp.einsum("becd,edf->becf", xg, p["w_gate"].astype(xg.dtype))
        act = jax.nn.silu if cfg.mlp == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        h = act(gt) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(h.dtype))

    combine = jax.vmap(lambda o, i, g: _combine_group(o, i, g, s))
    if bax:
        y = jax.shard_map(
            combine,
            mesh=mesh,
            in_specs=(bspec, bspec, bspec),
            out_specs=bspec,
        )(out_e, idx, gate_slot)
    else:
        y = combine(out_e, idx, gate_slot)
    y = parallel.shard(y, ("batch", "seq", "embed"))

    # Switch aux loss: E * Σ_e fraction_tokens_e · mean_prob_e
    aux = cfg.router_aux_coef * e * jnp.sum(
        frac.mean(axis=0) * probs.mean(axis=(0, 1))
    )
    return y, aux
