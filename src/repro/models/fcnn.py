"""The paper's FCNN [784, 500, 300, 10] with RACA neurons (§IV-C).

Hidden layers: binary stochastic Sigmoid neurons (comparators on noisy
crossbar columns); output layer: WTA binary stochastic SoftMax neurons with
majority voting over repeated decision trials.  Trained with the STE
surrogate (noise-aware QAT); inference runs the full stochastic circuit.

Also provides the digital baseline (same weights, exact sigmoid + softmax)
used for the accuracy-gap validation in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import analog as A
from repro.core import wta as W
from .config import ModelConfig


def init_fcnn(key, cfg: ModelConfig) -> dict:
    sizes = cfg.fcnn_layers
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k1, (a, b), jnp.float32) * (
            2.0 / a
        ) ** 0.5
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def fcnn_logits(
    params: dict,
    x: jax.Array,  # (B, 784) in [0, 1]
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Forward through hidden stochastic-binary layers, returning the final
    layer's pre-activations z (the WTA neurons' drive)."""
    n = len(cfg.fcnn_layers) - 1
    acfg = cfg.analog
    h = x
    for i in range(n - 1):
        ki = None if key is None else jax.random.fold_in(key, i)
        h = A.analog_dense(acfg, ki, h, params[f"w{i}"], params[f"b{i}"])
        if acfg.mode == "digital":
            h = jax.nn.sigmoid(h)  # digital baseline: exact sigmoid
    z = h @ params[f"w{n-1}"] + params[f"b{n-1}"]
    return z


def fcnn_loss(
    params: dict,
    batch: dict,  # {"image": (B,784), "label": (B,)}
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """Softmax cross-entropy on the WTA drive (the paper trains the SBNN in
    software with the standard surrogate; WTA replaces softmax at deploy)."""
    z = fcnn_logits(params, batch["image"], cfg, key)
    logp = jax.nn.log_softmax(z, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["label"][:, None], axis=1).mean()
    acc = (jnp.argmax(z, -1) == batch["label"]).mean()
    return nll, {"loss": nll, "acc": acc}


def fcnn_predict_digital(params: dict, x: jax.Array, cfg: ModelConfig):
    """Digital software baseline: exact (unquantized) sigmoid hidden layers
    + argmax — the paper's 'software-calculated' reference."""
    import dataclasses

    dcfg = dataclasses.replace(cfg, analog=cfg.analog.with_mode("digital"))
    z = fcnn_logits(params, x, dcfg, None)
    return jnp.argmax(z, axis=-1)


def fcnn_predict_raca(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    key: jax.Array,
    n_votes: int,
    vth0: Optional[float] = None,
) -> jax.Array:
    """Full RACA stochastic inference: every hidden layer re-samples its
    comparators per vote; the WTA output neuron accumulates winner counts
    over ``n_votes`` decision trials; argmax of the counts is the prediction
    (§III-C, Fig. 6)."""
    import dataclasses

    # deployment is always the hard stochastic circuit, regardless of the
    # training-time forward mode (expectation vs sampled)
    acfg = dataclasses.replace(cfg.analog, hard=True)
    cfg = dataclasses.replace(cfg, analog=acfg)
    theta = acfg.vth0 if vth0 is None else vth0
    sigma = W.wta_sigma_z(acfg.beta)

    def one_vote(carry, kv):
        counts = carry
        z = fcnn_logits(params, x, cfg, kv)
        res = W.wta_trials(
            jax.random.fold_in(kv, 99), z, n_trials=1, vth0=theta,
            sigma_z=sigma, beta=acfg.beta,
        )
        return counts + res.counts, None

    keys = jax.random.split(key, n_votes)
    counts0 = jnp.zeros(x.shape[:-1] + (cfg.fcnn_layers[-1],), jnp.float32)
    counts, _ = jax.lax.scan(one_vote, counts0, keys)
    return jnp.argmax(counts, axis=-1)
