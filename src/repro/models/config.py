"""Unified model configuration for every architecture family in the zoo.

One flexible dataclass (MaxText-style) covers dense decoder LMs, MoE,
SSM (Mamba-2), hybrid recurrent (RecurrentGemma), encoder-decoder (Whisper)
and VLM backbones (LLaVA).  Family-specific fields default to inert values.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.analog import DIGITAL, AnalogConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # decoder_lm | moe_lm | ssm | hybrid | encdec | vlm | fcnn

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab: int = 0
    max_seq: int = 8192

    mlp: str = "swiglu"            # swiglu | geglu | gelu | relu2
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma-style sqrt(d_model) embed scaling

    # Attention pattern: repeating unit of layer kinds, e.g. gemma2's
    # ("local", "global") or recurrentgemma's ("rec", "rec", "attn").
    layer_pattern: Tuple[str, ...] = ("global",)
    local_window: int = 4096
    attn_softcap: float = 0.0       # gemma2 logit soft-capping inside attn
    logit_softcap: float = 0.0      # gemma2 final-logit soft-capping
    post_norms: bool = False        # gemma2 post-block norms

    # MoE
    n_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # Recurrent (RG-LRU)
    lru_width: int = 0

    # Encoder-decoder
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq: int = 0                # fixed encoder length for decode shapes

    # VLM
    n_patches: int = 0              # prepended patch-embedding tokens

    # FCNN (the paper's network)
    fcnn_layers: Tuple[int, ...] = ()

    # Analog (RACA) execution
    analog: AnalogConfig = DIGITAL
    wta_head: bool = False          # WTA stochastic SoftMax readout

    # Performance knobs (hillclimbed in EXPERIMENTS.md §Perf)
    dtype: str = "bfloat16"
    remat_policy: str = "nothing"   # nothing | dots | full  (what to SAVE)
    scan_layers: bool = True
    scan_unroll: int = 1
    attn_probs_dtype: str = "float32"  # float32 | bfloat16 (scores/probs)
    attn_kv_chunk: int = 1024          # online-softmax KV chunk length
    # Pad query heads to this count (0 = off) so "model" divides the head
    # axis; padded heads' outputs are sliced away before w_o (numerically
    # identity, enables 16-way sharding of otherwise-replicated attention).
    attn_pad_heads: int = 0
    # Repeat KV heads up to n_heads before attention (GQA -> MHA layout) so
    # the flattened head axis shards; trades kv bytes for score sharding.
    gqa_repeat_kv: bool = False
    kv_cache_dtype: str = "same"       # same | int8 (stochastic-rounded)
    # cost_exact: fully unroll every lax.scan so XLA cost_analysis counts all
    # iterations (it otherwise counts a loop body ONCE).  Used by the
    # dry-run's roofline pass; compile-only, never executed.
    cost_exact: bool = False
    # force_fsdp: pin the FSDP decision (normally param_count-derived) so
    # reduced-layer cost-pass compiles keep the full model's sharding.
    force_fsdp: Optional[bool] = None

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def n_units(self) -> int:
        """Number of repeating pattern units (scanned)."""
        p = len(self.layer_pattern)
        assert self.n_layers % p == 0, (self.n_layers, self.layer_pattern)
        return self.n_layers // p

    def param_count(self) -> int:
        """Approximate parameter count N (for roofline MODEL_FLOPS=6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.mlp in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe_lm":
            mlp = mlp * self.n_experts + d * self.n_experts
        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            per = d * (2 * di + 2 * ns + nh) + di * d + di  # in/out proj + Δ
            layers = self.n_layers * per
        elif self.family == "hybrid":
            per_attn = attn + mlp
            di = self.lru_width or d
            per_rec = d * di * 2 + di * d + 2 * di * di // 8 + mlp  # approx
            n_attn = self.n_layers // 3
            layers = per_attn * n_attn + per_rec * (self.n_layers - n_attn)
        elif self.family == "encdec":
            layers = (self.enc_layers + self.dec_layers) * (attn + mlp)
            layers += self.dec_layers * attn  # cross-attention
        elif self.family == "fcnn":
            return sum(
                a * b + b
                for a, b in zip(self.fcnn_layers[:-1], self.fcnn_layers[1:])
            )
        else:
            layers = self.n_layers * (attn + mlp)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return layers + embed

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe_lm":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_expert = (3 if self.mlp in ("swiglu", "geglu") else 2) * d * f
        inactive = (self.n_experts - self.moe_topk) * per_expert
        return self.param_count() - self.n_layers * inactive
