"""Grouped-query attention with RoPE, local/global masking, soft-capping,
KV caches, and memory-efficient (online-softmax) chunked computation.

The chunked path never materializes the full (S, T) score matrix: queries
attend to KV chunks under a lax.scan carrying running (max, denom, acc) —
the standard flash-attention recurrence expressed in pure JAX so that GSPMD
can shard it (the Pallas kernel budget of this repo belongs to the paper's
crossbar pipeline, not attention).

Attention softmax is intentionally digital: the paper's WTA neuron emits
one-hot *samples*, not the weighted average attention requires (DESIGN.md
§5).  QKV/O projections do route through core.analog (linear readout) in
analog modes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import parallel
from repro.core import analog as A
from .config import ModelConfig
from .layers import apply_rope, dtype_of, softcap

NEG_INF = -2.0e38


class KVCache(NamedTuple):
    k: jax.Array      # (B, Smax, Hkv, Dh)
    v: jax.Array      # (B, Smax, Hkv, Dh)
    length: jax.Array  # (B,) int32 — tokens currently valid


def init_attn(key, cfg: ModelConfig, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    hd, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    init = lambda k, shape, fan: (
        jax.random.normal(k, shape, jnp.float32) * fan**-0.5
    ).astype(dt)
    return {
        "wq": init(ks[0], (d, h * hd), d),
        "wk": init(ks[1], (d, hkv * hd), d),
        "wv": init(ks[2], (d, hkv * hd), d),
        "wo": init(ks[3], (h * hd, d), h * hd),
    }


def _proj_cfg(cfg: ModelConfig) -> A.AnalogConfig:
    a = cfg.analog
    return a.with_mode("analog_linear") if a.mode == "analog_stochastic" else a


def qkv(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
):
    b, s, _ = x.shape
    acfg = _proj_cfg(cfg)
    keys = (None,) * 3 if key is None else jax.random.split(key, 3)
    q = A.analog_matmul(acfg, keys[0], x, p["wq"])
    k = A.analog_matmul(acfg, keys[1], x, p["wk"])
    v = A.analog_matmul(acfg, keys[2], x, p["wv"])
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = parallel.shard(q, ("batch", "seq", "heads", None))
    k = parallel.shard(k, ("batch", "seq", "kv_heads", None))
    v = parallel.shard(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _group(q: jax.Array, hkv: int) -> jax.Array:
    """(B,S,H,Dh) -> (B,S,Hkv,G,Dh) grouped query heads."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, hkv, h // hkv, dh)


def _maybe_expand(q, k, v, cfg: ModelConfig):
    """Perf transforms (§Perf): pad query heads to a shardable count and/or
    repeat KV heads to the full head count.  Both are numerically identity
    for the used heads; padded heads' outputs are sliced away by the caller
    (the w_o projection only consumes the real heads)."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    # head padding with grouped KV would scramble the q->kv grouping, so
    # padding implies the repeated-KV (MHA) layout
    repeat = cfg.gqa_repeat_kv or (
        cfg.attn_pad_heads and cfg.attn_pad_heads > h
    )
    if repeat and hkv < h:
        # repeat at the ORIGINAL head count (preserves q-head → kv-head
        # grouping), before any padding
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
        hkv = h
    if cfg.attn_pad_heads and cfg.attn_pad_heads > h:
        pad = cfg.attn_pad_heads - h
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if hkv == h:  # repeated layout: pad kv alongside q
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        h = cfg.attn_pad_heads
    if repeat:
        k = parallel.shard(k, ("batch", "seq", "heads", None))
        v = parallel.shard(v, ("batch", "seq", "heads", None))
    q = parallel.shard(q, ("batch", "seq", "heads", None))
    return q, k, v


def attend_full(
    q: jax.Array,  # (B,S,H,Dh)
    k: jax.Array,  # (B,T,Hkv,Dh)
    v: jax.Array,
    qpos: jax.Array,  # (S,) query positions
    kpos: jax.Array,  # (T,) key positions
    kind: str,        # global | local | none
    cfg: ModelConfig,
) -> jax.Array:
    """Online-softmax attention over KV chunks; returns (B,S,H,Dh).

    The mask is computed per KV chunk from positions — the full (S,T) score
    or bias matrix is never materialized (O(S·chunk) temporaries).  Probs
    dtype and chunk length are perf knobs (EXPERIMENTS.md §Perf)."""
    h_orig = q.shape[2]
    q, k, v = _maybe_expand(q, k, v, cfg)
    b, s, h, dh = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    pdt = jnp.dtype(cfg.attn_probs_dtype)
    qg = _group(q, hkv).astype(pdt) * jnp.asarray(dh**-0.5, pdt)
    kf = k.astype(pdt)
    vf = v.astype(pdt)
    kv_chunk = cfg.attn_kv_chunk
    nchunks = max(t // kv_chunk, 1)
    cs = t // nchunks
    assert t % cs == 0, (t, cs)

    kc = kf.reshape(b, nchunks, cs, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(b, nchunks, cs, hkv, dh).transpose(1, 0, 2, 3, 4)
    kposc = kpos.reshape(nchunks, cs)

    def step(carry, inp):
        m, l, acc = carry
        kci, vci, kpi = inp
        # scores: (B, Hkv, G, S, cs); accumulate in f32 on the MXU even for
        # bf16 operands
        sc = jnp.einsum(
            "bskgd,bckd->bkgsc", qg, kci,
            preferred_element_type=jnp.float32,
        )
        if cfg.attn_softcap > 0.0:
            sc = softcap(sc, cfg.attn_softcap)
        d = qpos[:, None] - kpi[None, :]  # (S, cs)
        if kind == "none":
            ok = jnp.ones(d.shape, bool)
        elif kind == "local":
            ok = (d >= 0) & (d < cfg.local_window)
        else:
            ok = d >= 0
        sc = sc + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        scale = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None]).astype(pdt)
        l_new = l * scale + p.sum(axis=-1).astype(jnp.float32)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, h // hkv, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, h // hkv, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, h // hkv, s, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, kposc),
        unroll=True if cfg.cost_exact else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)
    return out[:, :, :h_orig, :].astype(q.dtype)


def self_attention(
    p: dict,
    x: jax.Array,
    positions: jax.Array,  # (B, S)
    cfg: ModelConfig,
    kind: str = "global",
    key: Optional[jax.Array] = None,
    use_rope: bool = True,
) -> jax.Array:
    b, s, _ = x.shape
    kq = ko = None
    if key is not None:
        kq, ko = jax.random.split(key)
    q, k, v = qkv(p, x, cfg, kq)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    qpos = positions[0] if positions.ndim == 2 else positions
    out = attend_full(q, k, v, qpos, qpos, kind, cfg)
    out = out.reshape(b, s, -1)
    o = A.analog_matmul(_proj_cfg(cfg), ko, out, p["wo"])
    return parallel.shard(o, ("batch", "seq", "embed"))


def cross_attention(
    p: dict,
    x: jax.Array,
    enc_out: jax.Array,
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Decoder cross-attention over encoder outputs (no mask, no RoPE)."""
    b, s, _ = x.shape
    t = enc_out.shape[1]
    acfg = _proj_cfg(cfg)
    keys = (None,) * 4 if key is None else tuple(jax.random.split(key, 4))
    q = A.analog_matmul(acfg, keys[0], x, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim
    )
    k = A.analog_matmul(acfg, keys[1], enc_out, p["wk"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim
    )
    v = A.analog_matmul(acfg, keys[2], enc_out, p["wv"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim
    )
    qpos = jnp.arange(s)
    kpos = jnp.arange(t)
    out = attend_full(q, k, v, qpos, kpos, "none", cfg).reshape(b, s, -1)
    o = A.analog_matmul(acfg, keys[3], out, p["wo"])
    return parallel.shard(o, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache).
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int):
    dt = dtype_of(cfg)
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        length=jnp.zeros((batch,), jnp.int32),
    )


def cache_spec():
    """Logical axes for a stacked KV cache (leading layer axis)."""
    return ("layers", "batch", "seq", "kv_heads", None)


def _write_at(buf: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """buf: (B, Smax, Hkv, Dh); new: (B, 1, Hkv, Dh); pos: (B,) int32."""

    def one(b, n, p):
        return jax.lax.dynamic_update_slice(b, n, (p, 0, 0))

    return jax.vmap(one)(buf, new, pos)


def quantize_kv(x: jax.Array):
    """Symmetric per-(batch, pos, head) int8 quantization of K/V rows.

    The scale factors out of the head_dim contraction, so scoring against an
    int8 cache multiplies *scores* (not the cache) by scale/127 — no
    dequantized cache is ever materialized.  Conceptually this is the
    paper's conductance-grid programming applied to the cache (the
    stochastic-rounding variant runs through kernels/stoch_round on TPU)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)  # (..., Hkv)
    scale = jnp.maximum(scale, 1e-6)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None] * 127.0)
    return q.astype(jnp.int8), scale


def attend_one_token(
    q: jax.Array,        # (B, 1, H, Dh), RoPE already applied
    k_buf: jax.Array,    # (B, T, Hkv, Dh)  bf16/f32 or int8
    v_buf: jax.Array,
    pos: jax.Array,      # (B,) int32 — last valid key position
    cfg: ModelConfig,
    kind: str = "global",
    k_scale: Optional[jax.Array] = None,  # (B, T, Hkv) for int8 buffers
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention readout over a contiguous KV window.

    Shared by the dense decode path (k_buf = the per-slot max_len cache) and
    the paged decode path (k_buf = the blocks gathered through the block
    table) — using the *same* einsum/softmax computation on both is what
    makes dense-vs-paged greedy decode byte-identical.  Key positions beyond
    ``pos`` contribute exactly-zero probability (NEG_INF scores underflow to
    0 in the softmax), so a longer window only appends exact zeros.

    Returns the (B, 1, H*Dh) attention output before the w_o projection.
    """
    b = q.shape[0]
    int8_cache = k_buf.dtype == jnp.int8
    t = k_buf.shape[1]
    hkv = cfg.n_kv_heads
    cdt = (
        jnp.bfloat16 if int8_cache else jnp.dtype(cfg.attn_probs_dtype)
    )
    qg = _group(q, hkv).astype(cdt) * jnp.asarray(cfg.head_dim**-0.5, cdt)
    sc = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k_buf.astype(cdt),
        preferred_element_type=jnp.float32,
    )
    if int8_cache:
        sc = sc * (k_scale.transpose(0, 2, 1) / 127.0)[:, :, None, None, :]
    if cfg.attn_softcap > 0.0:
        sc = softcap(sc, cfg.attn_softcap)
    kpos = jnp.arange(t)[None]
    ok = kpos <= pos[:, None]
    if kind == "local":
        ok &= kpos > (pos[:, None] - cfg.local_window)
    sc = sc + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    w = jax.nn.softmax(sc, axis=-1)
    if int8_cache:
        w = w * (v_scale.transpose(0, 2, 1) / 127.0)[:, :, None, None, :]
    out = jnp.einsum(
        "bkgst,btkd->bskgd", w.astype(cdt), v_buf.astype(cdt),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, -1)


def decode_self_attention(
    p: dict,
    x: jax.Array,            # (B, 1, D)
    k_cache: jax.Array,      # (B, Smax, Hkv, Dh)  bf16 or int8
    v_cache: jax.Array,
    pos: jax.Array,          # (B,) current position (0-based write index)
    cfg: ModelConfig,
    kind: str = "global",
    use_rope: bool = True,
    k_scale: Optional[jax.Array] = None,  # (B, Smax, Hkv) for int8 caches
    v_scale: Optional[jax.Array] = None,
):
    """One-token attention against the cache.

    Returns (out, k_cache, v_cache[, k_scale, v_scale]).  Cache reads use
    mixed-precision einsums (operands stay in cache dtype, f32 MXU
    accumulation) — no full-cache f32 casts."""
    int8_cache = k_cache.dtype == jnp.int8
    q, k, v = qkv(p, x, cfg, None)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    if int8_cache:
        k8, ks = quantize_kv(k)
        v8, vs = quantize_kv(v)
        k_cache = _write_at(k_cache, k8, pos)
        v_cache = _write_at(v_cache, v8, pos)
        k_scale = jax.vmap(
            lambda bscale, n, p_: jax.lax.dynamic_update_slice(
                bscale, n, (p_, 0)
            )
        )(k_scale, ks[:, 0:1], pos)
        v_scale = jax.vmap(
            lambda bscale, n, p_: jax.lax.dynamic_update_slice(
                bscale, n, (p_, 0)
            )
        )(v_scale, vs[:, 0:1], pos)
    else:
        k_cache = _write_at(k_cache, k, pos)
        v_cache = _write_at(v_cache, v, pos)
    out = attend_one_token(
        q, k_cache, v_cache, pos, cfg, kind,
        k_scale=k_scale, v_scale=v_scale,
    ).astype(x.dtype)
    o = A.analog_matmul(_proj_cfg(cfg), None, out, p["wo"])
    if int8_cache:
        return o, k_cache, v_cache, k_scale, v_scale
    return o, k_cache, v_cache


# ---------------------------------------------------------------------------
# Paged decode path (block-table KV cache).
# ---------------------------------------------------------------------------


def paged_write(
    pages: jax.Array,   # (P, bs, ...) block pool (K/V or scale planes)
    new: jax.Array,     # (B, 1, ...) this step's K/V rows or scales
    table: jax.Array,   # (B, W) int32 block table (page ids)
    pos: jax.Array,     # (B,) int32 logical write position per slot
) -> jax.Array:
    """Scatter one token's K/V rows (or their scales) into each slot's
    current block.

    The target page is ``table[b, pos[b] // bs]``; the engine guarantees
    every slot's *current* page is exclusively owned, so the scatter never
    collides.  With prefix sharing, a page may appear in several slots'
    tables (aliased READS are fine — the gather is pure), but a shared
    page is never a write target: the engine's host-side copy-on-write
    pass forks (or deregisters) any still-shared page at ``pos // bs``
    before the decode step runs, which is what keeps this scatter
    collision-free.  Slots whose table row is all-trash (page 0, the
    engine's reserved scratch block) write into page 0, which no live
    request ever reads.  ``pos // bs`` is clamped into the table width
    so evicted slots whose ``pos`` keeps advancing stay in bounds.
    """
    bs = pages.shape[1]
    blk = jnp.clip(pos // bs, 0, table.shape[1] - 1)
    page_ids = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]
    page_ids = jnp.maximum(page_ids, 0)  # unassigned (-1) → trash page 0
    return pages.at[page_ids, pos % bs].set(new[:, 0].astype(pages.dtype))


def paged_gather(pages: jax.Array, table: jax.Array) -> jax.Array:
    """(P, bs, ...), (B, W) → (B, W·bs, ...) contiguous window.

    Block i of a slot's table holds logical positions [i·bs, (i+1)·bs), so
    the gathered window is exactly the prefix of the dense per-slot cache —
    the invariant the dense-vs-paged equivalence tests pin down.  Several
    table rows may name the same page (prefix sharing): the gather
    replicates it per slot, so shared and private layouts read
    identically.  Works for K/V pools (trailing (Hkv, Dh)) and their
    scale planes (trailing (Hkv,)).
    """
    b, w = table.shape
    bs = pages.shape[1]
    return pages[jnp.maximum(table, 0)].reshape(
        (b, w * bs) + pages.shape[2:]
    )


def paged_write_chunk(
    pages: jax.Array,   # (P, bs, ...) block pool (K/V or scale planes)
    new: jax.Array,     # (nbc, bs, ...) block-shaped chunk rows
    table_row: jax.Array,  # (Wp,) int32 — ONE request's block-table row
    b0: jax.Array,      # () int32 first block index the chunk covers
) -> jax.Array:
    """Scatter a suffix chunk's K/V rows (or scales) into its own pages.

    The chunk covers blocks ``[b0, b0 + nbc)`` of the request's table; the
    engine guarantees the chunk starts block-aligned (resume points and
    ``prefill_chunk`` are block multiples), so whole blocks scatter at
    once.  A ragged final block carries zero-padded rows beyond the prompt
    — those positions are masked out of every read until the decode step
    overwrites them row by row.  Page ids and ``b0`` are traced: one
    compile per (bucket, chunk shape) serves every page set."""
    nbc = new.shape[0]
    ids = jax.lax.dynamic_slice(table_row, (b0,), (nbc,))
    return pages.at[jnp.maximum(ids, 0)].set(new.astype(pages.dtype))


def _chunk_to_blocks(x: jax.Array, bs: int) -> jax.Array:
    """(1, c, ...) chunk rows → (nbc, bs, ...) zero-padded whole blocks."""
    c = x.shape[1]
    nbc = -(-c // bs)
    pad = [(0, 0), (0, nbc * bs - c)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)[0].reshape((nbc, bs) + x.shape[2:])


def paged_prefill_self_attention(
    p: dict,
    x: jax.Array,        # (1, c, D) — one request's suffix chunk
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — this layer's block pool
    v_pages: jax.Array,
    table_row: jax.Array,  # (Wp,) int32 — blocks covering the prompt bucket
    q0: jax.Array,       # () int32 absolute position of the chunk's start
    bucket: int,         # static padded prompt length (table covers it)
    cfg: ModelConfig,
    kind: str = "global",
    k_scale_pages: Optional[jax.Array] = None,  # (P, bs, Hkv) int8 pools
    v_scale_pages: Optional[jax.Array] = None,
    quant_seeds: Optional[jax.Array] = None,    # (nbc,) uint32, int8 pools
):
    """Suffix-chunk attention against the paged pool (the chunked-prefill
    building block).  Writes the chunk's K/V into its own pages, then the
    chunk's queries attend over the WHOLE prompt window ``[0, bucket)`` —
    shared prefix pages and the chunk's fresh pages alike — with absolute
    position offsets, so a suffix that starts mid-prompt masks exactly as
    if the full prompt had been prefilled monolithically.

    On TPU the gather+attend runs as the fused Pallas chunked-prefill
    kernel (kernels/prefill_attention.py).  Off TPU the bf16 path is the
    jnp gather + the same :func:`attend_full` used by the monolithic dense
    prefill — per-query online-softmax values are independent of which
    other queries share the tile, which is what makes suffix-only prefill
    byte-identical to prefilling the whole prompt (the dense-vs-paged and
    sharing-on-vs-off equivalence contracts).  int8 pools quantize each
    chunk block under its content-derived ``quant_seeds`` (shared blocks
    stay bit-identical across writers) and run the fused-dequant oracle.

    Returns (out (1, c, D) after w_o, k_pages, v_pages) — plus the scale
    planes for int8 pools.
    """
    int8_pool = k_pages.dtype == jnp.int8
    b, c, _ = x.shape
    bs = k_pages.shape[1]
    positions = jnp.broadcast_to(q0 + jnp.arange(c)[None], (b, c))
    q, k, v = qkv(p, x, cfg, None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    b0 = q0 // bs
    kb = _chunk_to_blocks(k, bs)   # (nbc, bs, Hkv, Dh)
    vb = _chunk_to_blocks(v, bs)
    if int8_pool:
        from repro.kernels import ops as KOPS

        kc, ks, vc, vs = [], [], [], []
        for i in range(kb.shape[0]):
            # per-block quantization under content-derived seeds: any
            # writer of the same block content produces bit-identical
            # codes, which is what keeps int8 blocks shareable
            k8, ksc, v8, vsc = KOPS.quantize_kv_pair_int8(
                kb[i], vb[i], quant_seeds[i]
            )
            kc.append(k8)
            ks.append(ksc)
            vc.append(v8)
            vs.append(vsc)
        k_pages = paged_write_chunk(k_pages, jnp.stack(kc), table_row, b0)
        v_pages = paged_write_chunk(v_pages, jnp.stack(vc), table_row, b0)
        k_scale_pages = paged_write_chunk(
            k_scale_pages, jnp.stack(ks), table_row, b0
        )
        v_scale_pages = paged_write_chunk(
            v_scale_pages, jnp.stack(vs), table_row, b0
        )
    else:
        k_pages = paged_write_chunk(k_pages, kb, table_row, b0)
        v_pages = paged_write_chunk(v_pages, vb, table_row, b0)
    if jax.default_backend() == "tpu" or int8_pool:
        from repro.kernels import ops as KOPS

        out = KOPS.paged_prefill_attention(
            q[0], k_pages, v_pages, table_row, q0,
            kind=kind,
            local_window=cfg.local_window,
            softcap=cfg.attn_softcap,
            k_scale=k_scale_pages if int8_pool else None,
            v_scale=v_scale_pages if int8_pool else None,
        )[None].astype(x.dtype)          # (1, c, H, Dh)
    else:
        # bit-parity route with the monolithic prefill: gather the window,
        # slice it to exactly the bucket length (same key chunking as
        # attend_full over the full prompt), same online-softmax helper
        k_buf = paged_gather(k_pages, table_row[None])[:, :bucket]
        v_buf = paged_gather(v_pages, table_row[None])[:, :bucket]
        qpos = q0 + jnp.arange(c)
        kpos = jnp.arange(bucket)
        out = attend_full(q, k_buf, v_buf, qpos, kpos, kind, cfg)
    # w_o through the same direct matmul as the monolithic lm_prefill (the
    # byte-identity oracle), not the decode path's analog projection
    o = out.reshape(b, c, -1) @ p["wo"].astype(x.dtype)
    if int8_pool:
        return o, k_pages, v_pages, k_scale_pages, v_scale_pages
    return o, k_pages, v_pages


def paged_decode_self_attention(
    p: dict,
    x: jax.Array,        # (B, 1, D)
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — this layer's block pool
    v_pages: jax.Array,
    table: jax.Array,    # (B, W) int32 block table (W·bs covers max(pos)+1)
    pos: jax.Array,      # (B,) int32
    cfg: ModelConfig,
    kind: str = "global",
    use_rope: bool = True,
    k_scale_pages: Optional[jax.Array] = None,  # (P, bs, Hkv) int8 pools
    v_scale_pages: Optional[jax.Array] = None,
    quant_seed: Optional[jax.Array] = None,     # uint32 scalar, int8 pools
    write: bool = True,
):
    """One-token attention against a paged (block-table) KV cache.

    Writes this step's K/V into each slot's current block, then attends over
    the W gathered blocks only — O(W·bs) work per token instead of
    O(max_len).  On TPU the gather+attend runs as the fused Pallas
    paged-attention kernel (kernels/paged_attention.py); elsewhere it is the
    pure-jnp gather + the shared :func:`attend_one_token` (bit-identical to
    the dense path over the valid prefix).

    With an int8 pool (``k_pages.dtype == int8``; scale planes + a
    ``quant_seed`` provided) the new K/V row is quantized with unbiased
    stochastic rounding (kernels.ops.quantize_kv_int8 — the paper's
    conductance-programming primitive applied to cache writes) and the
    per-(page, slot-in-page, head) scales ride through the same block
    table; dequantization is fused into the attention math on both
    backends (scores × k_scale/127, weights × v_scale/127 — the cache is
    never dequantized in memory).

    Returns (out, k_pages, v_pages) — plus (k_scale_pages, v_scale_pages)
    for int8 pools.
    """
    int8_pool = k_pages.dtype == jnp.int8
    q, k, v = qkv(p, x, cfg, None)
    if use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    if not write:
        # speculative-verify re-read: the draft step already wrote this
        # position's K/V (bit-identical rows — same inputs, same seed
        # trajectory), so the verifier attends the pages as they are.
        # Skipping the write keeps the pool untouched (int8 pools would
        # otherwise re-quantize under a different quant_step and change
        # bits) and lets the caller drop the pool from its scan carry.
        pass
    elif int8_pool:
        from repro.kernels import ops as KOPS

        k8, ks, v8, vs = KOPS.quantize_kv_pair_int8(k, v, quant_seed)
        k_pages = paged_write(k_pages, k8, table, pos)
        v_pages = paged_write(v_pages, v8, table, pos)
        k_scale_pages = paged_write(k_scale_pages, ks, table, pos)
        v_scale_pages = paged_write(v_scale_pages, vs, table, pos)
    else:
        k_pages = paged_write(k_pages, k, table, pos)
        v_pages = paged_write(v_pages, v, table, pos)
    if jax.default_backend() == "tpu":
        from repro.kernels import ops as KOPS

        out = KOPS.paged_attention(
            q[:, 0], k_pages, v_pages, table, pos,
            kind=kind,
            local_window=cfg.local_window,
            softcap=cfg.attn_softcap,
            k_scale=k_scale_pages if int8_pool else None,
            v_scale=v_scale_pages if int8_pool else None,
        )[:, None].reshape(x.shape[0], 1, -1)
    else:
        k_buf = paged_gather(k_pages, table)
        v_buf = paged_gather(v_pages, table)
        out = attend_one_token(
            q, k_buf, v_buf, pos, cfg, kind,
            k_scale=paged_gather(k_scale_pages, table)
            if int8_pool else None,
            v_scale=paged_gather(v_scale_pages, table)
            if int8_pool else None,
        )
    out = out.astype(x.dtype)
    o = A.analog_matmul(_proj_cfg(cfg), None, out, p["wo"])
    if int8_pool:
        return o, k_pages, v_pages, k_scale_pages, v_scale_pages
    return o, k_pages, v_pages
