"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    a_t = a^(c·r_t),  a = sigmoid(Λ)          (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

computed with an associative scan (log-depth, sub-quadratic — which is why
recurrentgemma runs the long_500k shape).  The block wraps the RG-LRU with
a temporal conv and a GeLU gate branch as in Griffin.

Both sigmoid gates are exactly the paper's stochastic-binary neuron shape:
in ``analog_stochastic`` mode they become comparator-sampled Bernoulli gates
(unbiased: E[Bern(σ(z))] = σ(z)) — see DESIGN.md §5.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import parallel
from repro.core import analog as A
from repro.core import neurons
from .config import ModelConfig
from .layers import dtype_of

_C_EXP = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 7)
    init = lambda k, shape, fan: (
        jax.random.normal(k, shape, jnp.float32) * fan**-0.5
    ).astype(dt)
    return {
        "w_main": init(ks[0], (d, w), d),      # branch 1 -> conv -> RG-LRU
        "w_gate_br": init(ks[1], (d, w), d),   # branch 2 -> GeLU
        "w_out": init(ks[2], (w, d), w),
        "conv_w": (
            jax.random.normal(ks[3], (4, w), jnp.float32) * 0.1
        ).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": init(ks[4], (w, w), w),          # recurrence gate
        "wx": init(ks[5], (w, w), w),          # input gate
        "ba": jnp.full((w,), 2.0, jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": jnp.linspace(2.0, 5.0, w).astype(jnp.float32),  # a=σ(Λ)
    }


def _conv(u, w, b, cache=None):
    """f32-accumulated causal conv (matches decode-step recomputation).

    ``cache`` (B, K-1, W), when given, replaces the zero left-pad with
    the raw conv inputs preceding the chunk (a resumable prefill); a zero
    cache is value-identical to the zero pad, which is what keeps
    single-chunk prefills bit-identical to the monolithic path."""
    k = w.shape[0]
    uf = u.astype(jnp.float32)
    if cache is None:
        pad = jnp.pad(uf, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([cache.astype(jnp.float32), uf], axis=1)
    out = jnp.zeros_like(uf)
    wf = w.astype(jnp.float32)
    for i in range(k):
        out = out + pad[:, i : i + u.shape[1], :] * wf[i]
    return (out + b.astype(jnp.float32)).astype(u.dtype)


def rglru_scan(
    x: jax.Array,       # (B,S,W) gated input, f32
    log_a: jax.Array,   # (B,S,W) per-step log decay, f32 (<0)
    h0: Optional[jax.Array] = None,
) -> jax.Array:
    """h_t = a_t·h_{t-1} + b_t via associative scan; returns all h (B,S,W)."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * x
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(
    p: dict,
    x: jax.Array,  # (B,S,D)
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    acfg = cfg.analog
    pcfg = (
        acfg.with_mode("analog_linear")
        if acfg.mode == "analog_stochastic"
        else acfg
    )
    ks = (None,) * 4 if key is None else tuple(jax.random.split(key, 4))
    main = A.analog_matmul(pcfg, ks[0], x, p["w_main"])
    gate_br = A.analog_matmul(pcfg, ks[1], x, p["w_gate_br"])
    main = _conv(main, p["conv_w"], p["conv_b"])
    main = parallel.shard(main, ("batch", "seq", "ffn"))

    mf = main.astype(jnp.float32)
    za = mf @ p["wa"].astype(jnp.float32) + p["ba"]
    zx = mf @ p["wx"].astype(jnp.float32) + p["bx"]
    if acfg.mode == "analog_stochastic" and ks[2] is not None:
        # RACA: both gates are comparator-sampled binary neurons (Eq. 8/13).
        r = neurons.sigmoid_neuron_calibrated(ks[2], za, beta=acfg.beta)
        i = neurons.sigmoid_neuron_calibrated(ks[3], zx, beta=acfg.beta)
    else:
        r = jax.nn.sigmoid(za)
        i = jax.nn.sigmoid(zx)
    log_a_unit = -jax.nn.softplus(-p["lam"])  # log σ(Λ) < 0
    log_a = _C_EXP * r * log_a_unit[None, None, :]
    h = rglru_scan(i * mf, log_a)
    y = h.astype(x.dtype) * jax.nn.gelu(gate_br, approximate=True)
    out = A.analog_matmul(pcfg, None, y, p["w_out"])
    return parallel.shard(out, ("batch", "seq", "embed"))


def rglru_prefill(
    p: dict,
    x: jax.Array,  # (B,S,D)
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Forward that also returns decode state:
    (out (B,S,D), conv input tail (B,3,W), final hidden h (B,W)).

    Delegates to :func:`rglru_prefill_chunk` with zeroed carry — the
    monolithic prefill IS the single-chunk case, so the two can never
    drift apart numerically (the dense-vs-paged byte-identity anchor)."""
    b = x.shape[0]
    w = p["w_main"].shape[1]
    return rglru_prefill_chunk(
        p, x,
        jnp.zeros((b, 3, w), x.dtype),
        jnp.zeros((b, w), jnp.float32),
        cfg,
    )


def rglru_prefill_chunk(
    p: dict,
    x: jax.Array,           # (B,S,D) — one suffix chunk
    conv_cache: jax.Array,  # (B,3,W) raw conv inputs preceding the chunk
    h0: jax.Array,          # (B,W) f32 hidden state entering the chunk
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One chunk of a resumable prefill: :func:`rglru_prefill` math with
    the recurrence carried across chunks.  With zero (conv_cache, h0) and
    the chunk covering the whole prompt this is bit-identical to
    ``rglru_prefill`` — the chunked serving prefill's equivalence anchor.
    Returns (out (B,S,D), new conv tail (B,3,W), last hidden h (B,W))."""
    main = x @ p["w_main"].astype(x.dtype)
    gate_br = x @ p["w_gate_br"].astype(x.dtype)
    new_tail = jnp.concatenate(
        [conv_cache.astype(main.dtype), main], axis=1
    )[:, -3:, :]
    main_c = _conv(main, p["conv_w"], p["conv_b"], cache=conv_cache)
    mf = main_c.astype(jnp.float32)
    za = mf @ p["wa"].astype(jnp.float32) + p["ba"]
    zx = mf @ p["wx"].astype(jnp.float32) + p["bx"]
    r = jax.nn.sigmoid(za)
    i = jax.nn.sigmoid(zx)
    log_a_unit = -jax.nn.softplus(-p["lam"])
    log_a = _C_EXP * r * log_a_unit[None, None, :]
    h = rglru_scan(i * mf, log_a, h0=h0.astype(jnp.float32))
    y = h.astype(x.dtype) * jax.nn.gelu(gate_br, approximate=True)
    out = y @ p["w_out"].astype(y.dtype)
    return out, new_tail, h[:, -1, :]


def rglru_decode_step(
    p: dict,
    x: jax.Array,       # (B,1,D)
    conv_cache: jax.Array,  # (B,3,W)
    h: jax.Array,           # (B,W) f32
    cfg: ModelConfig,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    main = x[:, 0, :] @ p["w_main"].astype(x.dtype)   # (B,W)
    gate_br = x[:, 0, :] @ p["w_gate_br"].astype(x.dtype)
    window = jnp.concatenate([conv_cache, main[:, None, :]], axis=1)
    w = p["conv_w"]
    conv_out = (
        jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )
    # round through the activation dtype to match the prefill path exactly
    conv_out = conv_out.astype(x.dtype).astype(jnp.float32)
    new_conv = window[:, 1:, :]
    za = conv_out @ p["wa"].astype(jnp.float32) + p["ba"]
    zx = conv_out @ p["wx"].astype(jnp.float32) + p["bx"]
    r = jax.nn.sigmoid(za)
    i = jax.nn.sigmoid(zx)
    log_a = _C_EXP * r * (-jax.nn.softplus(-p["lam"]))[None, :]
    a = jnp.exp(log_a)
    h = a * h + jnp.sqrt(jnp.maximum(1 - jnp.square(a), 1e-12)) * (
        i * conv_out
    )
    y = h.astype(x.dtype) * jax.nn.gelu(gate_br, approximate=True)
    out = (y @ p["w_out"].astype(y.dtype))[:, None, :]
    return out, new_conv, h
