"""granite-moe-3b-a800m [moe]: 32L d1536 24H (kv=8) ff512/expert
vocab49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

The MoE router is the closest conceptual fit to the paper's WTA circuit:
top-8 routing as an 8-winner-take-all race (core.wta.wta_topk).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe_lm",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    mlp="swiglu",
    n_experts=40,
    moe_topk=8,
    tie_embeddings=True,
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=32, vocab=256, n_experts=8, moe_topk=2, max_seq=128,
        capacity_factor=8.0,  # drop-free for exactness tests
    )
