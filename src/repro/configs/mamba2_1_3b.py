"""mamba2-1.3b [ssm]: 48L d2048 (attention-free) vocab50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

No softmax anywhere -> the paper's WTA neuron applies only as an optional
LM-head sampler; the silu gate branch is the stochastic-binary candidate
(DESIGN.md §5).  O(1) decode state -> runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    d_ff=0,
    vocab=50280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    max_seq=525_000,
)

SKIP_SHAPES = {}  # attention-free: O(1) decode state -> 500k OK


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, ssm_state=16, ssm_headdim=16,
        ssm_chunk=8, vocab=256, max_seq=128,
    )
