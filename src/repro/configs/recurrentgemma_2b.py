"""recurrentgemma-2b [hybrid]: 26L d2560 10H (kv=1, MQA) ff7680
vocab256000 — RG-LRU + local attention, 1 attn : 2 rec.
[arXiv:2402.19427; hf]

26 layers = 2 scanned units of 13; each unit holds local-attn layers at
positions 2,5,8,11 (4 attn + 9 rec per unit = 8 attn + 18 rec total,
matching the released model's counts; unit-internal offsets differ from the
released checkpoint by one position — structurally equivalent).
"""

from repro.models.config import ModelConfig

_UNIT = (
    "rec", "rec", "local",
    "rec", "rec", "local",
    "rec", "rec", "local",
    "rec", "rec", "local",
    "rec",
)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256_000,
    mlp="geglu",
    layer_pattern=_UNIT,
    local_window=2048,
    lru_width=2560,
    tie_embeddings=True,
    embed_scale=True,
    max_seq=525_000,
)

SKIP_SHAPES = {}  # sub-quadratic: RG-LRU + 2048-window local attn -> 500k OK


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, layer_pattern=("rec", "rec", "local"),
        d_model=64, n_heads=4, n_kv_heads=1, d_head=16, d_ff=128,
        lru_width=64, vocab=256, local_window=16, max_seq=128,
    )
