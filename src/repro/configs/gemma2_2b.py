"""gemma2-2b [dense]: 26L d2304 8H (kv=4) ff9216 vocab256000 — local+global
alternating attention, attn/final logit soft-capping, post-norms, tied
embeddings.  [arXiv:2408.00118; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="decoder_lm",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab=256_000,
    mlp="geglu",
    layer_pattern=("local", "global"),
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    embed_scale=True,
    max_seq=33_000,
)

SKIP_SHAPES = {
    "long_500k": "alternating local+GLOBAL attention (global layers are "
    "quadratic at 500k)"
}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, local_window=16, max_seq=128,
    )
