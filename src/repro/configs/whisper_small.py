"""whisper-small [audio]: 12L(enc)+12L(dec) d768 12H (kv=12) ff3072
vocab51865 — encoder-decoder; conv-mel frontend is a STUB (input_specs()
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab=51865,
    mlp="gelu",
    enc_seq=1500,       # encoder length for decode shapes (30 s of audio)
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "full-attention enc-dec (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=4, enc_layers=2, dec_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
        enc_seq=16, max_seq=64,
    )
