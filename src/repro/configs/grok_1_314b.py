"""grok-1-314b [moe]: 64L d6144 48H (kv=8) ff32768 vocab131072, MoE 8
experts top-2.  [hf:xai-org/grok-1; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe_lm",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    mlp="geglu",
    n_experts=8,
    moe_topk=2,
    attn_softcap=30.0,   # grok uses attention logit capping
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=64, vocab=256, n_experts=4, moe_topk=2, max_seq=128,
        capacity_factor=4.0,  # drop-free for exactness tests
    )
