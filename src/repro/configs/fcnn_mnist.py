"""The paper's own FCNN [784, 500, 300, 10] on (surrogate) MNIST (§IV-C)."""

import dataclasses

from repro.core.analog import AnalogConfig
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.models.config import ModelConfig

_DEVICE = calibrate_v_read(DeviceParams(), n_rows=784)

CONFIG = ModelConfig(
    name="fcnn-mnist",
    family="fcnn",
    fcnn_layers=(784, 500, 300, 10),
    analog=AnalogConfig(
        mode="analog_stochastic", device=_DEVICE, wta_trials=32,
        # training forward uses the expectation (E[Bern(sigma)] = sigma, the
        # SBNN surrogate); deployment (fcnn_predict_raca) samples hard.
        hard=False,
    ),
    wta_head=True,
    dtype="float32",
)

SKIP_SHAPES = {}


def smoke_config() -> ModelConfig:
    return dataclasses.replace(CONFIG, fcnn_layers=(64, 32, 16, 10))
