"""nemotron-4-340b [dense]: 96L d18432 96H (kv=8) ff73728 vocab256000 —
GQA + squared-ReLU MLP (non-gated).  [arXiv:2402.16819; unverified]

Squared-ReLU is not sigmoid-shaped, so the paper's stochastic-binary neuron
is inapplicable as the hidden activation here; analog execution uses the
linear-readout mode (noise-aware training) only — DESIGN.md §5.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="decoder_lm",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_head=192,
    d_ff=73728,
    vocab=256_000,
    mlp="relu2",
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, max_seq=128,
    )
