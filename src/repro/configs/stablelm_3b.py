"""stablelm-3b [dense]: 32L d2560 32H (kv=32, MHA) ff6912 vocab50304.
[hf:stabilityai/stablelm-2-1_6b; unverified]

Chosen as the technique-representative hillclimb cell: RACA analog MLP +
WTA sampling head integrate here for §Perf (EXPERIMENTS.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="decoder_lm",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_head=80,
    d_ff=6912,
    vocab=50304,
    mlp="swiglu",
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab=256, max_seq=128,
    )
