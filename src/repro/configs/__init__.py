"""Per-architecture configs (assigned pool + the paper's FCNN)."""

from importlib import import_module

_MODULES = {
    "llava-next-34b": "llava_next_34b",
    "whisper-small": "whisper_small",
    "stablelm-3b": "stablelm_3b",
    "gemma2-2b": "gemma2_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "nemotron-4-340b": "nemotron_4_340b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "grok-1-314b": "grok_1_314b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-1.3b": "mamba2_1_3b",
    "fcnn-mnist": "fcnn_mnist",
}

ASSIGNED_ARCHS = [a for a in _MODULES if a != "fcnn-mnist"]


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke_config(name: str):
    return _mod(name).smoke_config()


def skip_shapes(name: str) -> dict:
    return getattr(_mod(name), "SKIP_SHAPES", {})


from .shapes import SHAPES, ShapeSpec  # noqa: E402


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped ones annotated."""
    out = []
    for arch in ASSIGNED_ARCHS:
        skips = skip_shapes(arch)
        for shape in SHAPES:
            skipped = shape in skips
            if skipped and not include_skipped:
                continue
            out.append((arch, shape, skips.get(shape)))
    return out
