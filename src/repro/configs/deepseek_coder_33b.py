"""deepseek-coder-33b [dense]: 62L d7168 56H (kv=8) ff19200 vocab32256 —
llama-arch.  [arXiv:2401.14196; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="decoder_lm",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=19200,
    vocab=32256,
    mlp="swiglu",
    rope_theta=100_000.0,
    max_seq=33_000,
)

SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, max_seq=128,
    )
