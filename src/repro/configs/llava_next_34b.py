"""llava-next-34b [vlm]: 60L d7168 56H (kv=8) ff20480 vocab64000.

AnyRes tiling / vision tower is a STUB per the assignment: input_specs()
provides precomputed patch embeddings (576 tokens for the base 336px tile)
prepended to the text sequence.  Backbone is the Yi-34B-class dense LM.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    mlp="swiglu",
    rope_theta=5_000_000.0,
    n_patches=576,
    max_seq=34_000,
)

# full attention only -> long_500k skipped (quadratic KV at 524k).
SKIP_SHAPES = {"long_500k": "pure full-attention arch (quadratic at 500k)"}


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, n_patches=8, max_seq=128,
    )
