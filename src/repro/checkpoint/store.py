"""Mesh-agnostic checkpointing with async save and elastic restore.

Format: one .npz of host-gathered leaves (path-addressed names) plus a JSON
manifest (step, leaf paths/shapes/dtypes, integrity checksum).  Writes are
atomic (tmp dir + rename) so a crash mid-save never corrupts the latest
checkpoint.  Because leaves are stored unsharded, a checkpoint written on a
512-chip mesh restores onto ANY mesh — re-sharding happens at load via the
target shardings (elastic scaling: survive with whatever devices remain).

At true fleet scale this single-host gather becomes per-host sharded files;
the manifest/atomic-rename/async structure is the part that carries over,
and the interface (save/load pytree) is storage-layout agnostic.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return names, leaves, treedef


def _to_numpy(leaf) -> tuple[np.ndarray, str]:
    """Host array + original dtype tag (npz can't store bf16 / PRNG keys)."""
    if hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
        leaf.dtype, jax.dtypes.extended
    ):
        return np.asarray(jax.random.key_data(leaf)), "prng_key"
    a = np.asarray(jax.device_get(leaf))
    if a.dtype == jax.numpy.bfloat16:
        return a.astype(np.float32), "bfloat16"
    return a, str(a.dtype)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking atomic save; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    converted = [_to_numpy(l) for l in leaves]
    arrays = [c[0] for c in converted]
    dtypes = [c[1] for c in converted]
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "leaves.npz"), **dict(zip(names, arrays)))
    digest = hashlib.sha256()
    for n, a in zip(names, arrays):
        digest.update(n.encode())
        digest.update(np.ascontiguousarray(a).tobytes()[:4096])
    manifest = {
        "step": step,
        "leaves": {
            n: {"shape": list(a.shape), "dtype": dt}
            for n, a, dt in zip(names, arrays, dtypes)
        },
        "checksum": digest.hexdigest(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (
            re.match(r"step_(\d+)$", d) for d in os.listdir(directory)
        )
        if m
    ]
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    step: int,
    like: Any,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like``; device_put with ``shardings``
    when given (elastic re-shard onto the current mesh)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    names, leaves, treedef = _flatten_with_names(like)
    out = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    for i, (n, l) in enumerate(zip(names, leaves)):
        a = data[n]
        want = manifest["leaves"][n]
        assert list(a.shape) == want["shape"], (n, a.shape, want)
        if want["dtype"] == "prng_key":
            arr = jax.random.wrap_key_data(jax.numpy.asarray(a))
        elif want["dtype"] == "bfloat16":
            arr = a.astype(jax.numpy.bfloat16)
        else:
            arr = a.astype(l.dtype) if hasattr(l, "dtype") else a
        if shard_leaves is not None and shard_leaves[i] is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpointing off the critical path + retention policy."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # Gather on the caller thread (cheap host copies), write in the
        # background so the train loop keeps stepping.
        names, leaves, _ = _flatten_with_names(tree)
        arrays = [np.asarray(jax.device_get(l)) for l in leaves]
        host_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree), arrays
        )

        def work():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1))
            for m in (
                re.match(r"step_(\d+)$", d)
                for d in os.listdir(self.directory)
            )
            if m
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )
