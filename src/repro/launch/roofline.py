"""Roofline accounting from compiled dry-run artifacts (TPU v5e targets).

Terms (per EXPERIMENTS.md §Roofline; the compiled module is the SPMD
per-device program, so cost_analysis numbers are already per-chip):

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

collective_bytes comes from parsing the optimized HLO text: per op type the
bytes a chip moves over ICI are estimated as (ring algorithms, (n-1)/n ≈ 1):
all-gather → result bytes; reduce-scatter → operand bytes; all-reduce →
2 × operand bytes; all-to-all / collective-permute → operand bytes.
"""

from __future__ import annotations

import re
from typing import Optional

PEAK_FLOPS = 197e12     # bf16 FLOP/s per v5e chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link (formula uses one link per chip)
HBM_PER_CHIP = 16e9     # v5e HBM capacity

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\("
    r"(?P<operands>[^)]*)\)"
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-type byte totals (per device) from optimized HLO."""
    out = {
        "all-reduce": 0,
        "all-gather": 0,
        "reduce-scatter": 0,
        "all-to-all": 0,
        "collective-permute": 0,
        "count": 0,
    }
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        res_b = _shape_bytes(m.group("result"))
        opd_b = _shape_bytes(m.group("operands"))
        if op == "all-gather":
            b = res_b
        elif op == "all-reduce":
            b = 2 * opd_b
        else:  # reduce-scatter / all-to-all / collective-permute
            b = opd_b
        out[op] += b
        out["count"] += 1
    out["total_bytes"] = sum(
        out[k] for k in
        ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
    )
    return out


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step (global): 6·N·D train, 2·N·D forward-only;
    MoE uses active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            d = shape.global_batch * (shape.seq_len + 448)
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token


def roofline_terms(record: dict) -> dict:
    """record: one dry-run cell dict (see dryrun.py)."""
    flops_pd = record["cost"].get("flops", 0.0)
    bytes_pd = record["cost"].get("bytes accessed", 0.0)
    coll_pd = record["collectives"]["total_bytes"]
    t_c = flops_pd / PEAK_FLOPS
    t_m = bytes_pd / HBM_BW
    t_x = coll_pd / ICI_BW
    # key= compares times ONLY: bare tuple max would fall through to the
    # label strings on tied times ("memory" > "compute" alphabetically).
    # With key=, max keeps the FIRST maximal entry, so ties resolve in
    # listed order: compute, then memory, then collective.
    dom = max(
        (t_c, "compute"), (t_m, "memory"), (t_x, "collective"),
        key=lambda t: t[0],
    )[1]
    mf = record["model_flops_per_chip"]
    out = {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_x,
        "dominant": dom,
        "useful_flops_ratio": (mf / flops_pd) if flops_pd else 0.0,
        "bound_s": max(t_c, t_m, t_x),
    }
    # roofline fraction: useful work over the time the dominant term costs
    out["roofline_fraction"] = (
        (mf / PEAK_FLOPS) / out["bound_s"] if out["bound_s"] > 0 else 0.0
    )
    return out


def improvement_hint(record: dict, ro: dict) -> str:
    """One sentence: what would move the dominant term down."""
    kind = record.get("kind", "train")
    dom = ro["dominant"]
    ufr = ro["useful_flops_ratio"]
    coll = record.get("collectives", {})
    if dom == "compute":
        if ufr < 0.5:
            return ("compute is mostly remat/replication waste — relax the "
                    "remat policy or shard the replicated attention heads")
        return ("near-useful-compute bound — raise arithmetic intensity "
                "(larger per-chip batch) or accept")
    if dom == "memory":
        if kind == "decode":
            return ("decode reads the whole KV cache per token — shrink "
                    "local-window caches / quantize KV to int8")
        return ("activation traffic dominates — chunk the f32 logits/CE, "
                "save dots instead of recomputing (remat policy)")
    # collective
    big = max(
        ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute"),
        key=lambda k: coll.get(k, 0),
    )
    return (f"{big} dominates — overlap it with compute, reduce its "
            "precision (int8/bf16), or reshard to keep it on-pod")
