"""Serving launcher: continuous-batching engine against a (randomly
initialized or checkpointed) model, greedy or WTA-stochastic sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --smoke \
        --requests 4 --new-tokens 16 [--wta] [--static] \
        [--ckpt-dir ckpts/stablelm-3b]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import latest_step, load_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine, StaticServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--wta", action="store_true",
                    help="WTA stochastic SoftMax sampling (the paper's head)")
    ap.add_argument("--static", action="store_true",
                    help="static-batch reference engine (no slot refill)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous-batching batch width)")
    ap.add_argument("--kv-layout", choices=("paged", "dense"),
                    default="paged",
                    help="KV cache layout: paged block pool (default) or "
                         "the dense per-slot max_len oracle")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="pool size in blocks; 0 = dense-parity capacity")
    ap.add_argument("--kv-dtype", choices=("same", "int8"), default="same",
                    help="KV cache dtype: 'int8' stores stochastically "
                         "rounded int8 codes + scale planes (half the "
                         "decode HBM bytes; doubled paged-pool capacity)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable content-hash prompt-block sharing with "
                         "copy-on-write in the paged pool")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="max prefill tokens computed per engine tick "
                         "(paged layout; 0 = whole bucket at once); also "
                         "the partial-prefix resume grid for "
                         "recurrent/SSM families")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the paged pool + decode step over a "
                         "(data, model) mesh of the local devices (use "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N"
                         " for a multi-device CPU mesh)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-axis size of the serving mesh (--sharded); "
                         "remaining devices go to the data axis")
    ap.add_argument("--priority", type=int, default=1,
                    help="priority class for the submitted requests: 0 = "
                         "interactive (may preempt lower classes under "
                         "pool pressure, spilling their pages to host), "
                         "1 = batch (default)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline in ms from submission; a "
                         "request past it is evicted with reason "
                         "'deadline' (default: none)")
    ap.add_argument("--no-preemption", action="store_true",
                    help="disable priority preemption (higher-priority "
                         "arrivals back-pressure instead of spilling a "
                         "lower-priority victim's KV pages to host)")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="self-speculative decoding: draft up to K tokens "
                         "per tick with the fused decode step, verify the "
                         "run in one read-only pass, roll back at the "
                         "first mismatch (paged layout; 0 = off; greedy "
                         "streams are byte-identical to plain decode)")
    ap.add_argument("--spill-budget-bytes", type=int, default=None,
                    help="cap on host bytes held by preemption spill "
                         "records; oldest records are dropped at the cap "
                         "and their victims recompute from the prompt on "
                         "restore (default: unbounded)")
    ap.add_argument("--device-backend", default="sim",
                    help="analog device backend: 'sim' (ideal math) or "
                         "'sim_faulty' (seeded ReRAM fault model: stuck "
                         "cells, conductance drift, readout noise)")
    ap.add_argument("--stuck-rate", type=float, default=0.0,
                    help="fraction of crossbar cells stuck at SA0/SA1 "
                         "(sim_faulty; split evenly between the rails)")
    ap.add_argument("--drift-nu", type=float, default=0.0,
                    help="conductance drift exponent: multiplier "
                         "(1+clock)^-nu on the fault clock (sim_faulty)")
    ap.add_argument("--read-sigma-inflation", type=float, default=0.0,
                    help="fractional inflation of comparator read-noise "
                         "sigma (sim_faulty)")
    ap.add_argument("--comparator-offset", type=float, default=0.0,
                    help="additive comparator threshold offset in "
                         "normalized units (sim_faulty)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic stuck-cell maps "
                         "(sim_faulty)")
    ap.add_argument("--canary-interval", type=int, default=0,
                    help="run a known-answer crossbar canary probe every "
                         "N engine ticks (0 = off); failures feed the "
                         "degradation ladder and tile retirement")
    ap.add_argument("--n-redundant-reads", type=int, default=1,
                    help="baseline comparator re-reads per WTA decode "
                         "sample, majority-voted (1 = single read)")
    ap.add_argument("--tile-retire-threshold", type=float, default=0.0,
                    help="retire crossbar tiles whose stuck-cell density "
                         "exceeds this fraction after a canary failure "
                         "(0 = never retire)")
    ap.add_argument("--degrade", action="store_true",
                    help="enable the graceful-degradation ladder "
                         "(disable speculation -> raise redundant reads "
                         "-> shed batch admissions) driven by canary "
                         "failures and sanity evictions")
    ap.add_argument("--ckpt-dir")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(
        cfg, wta_head=args.wta, kv_cache_dtype=args.kv_dtype
    )
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            like = jax.eval_shape(lambda: params)
            state = load_checkpoint(args.ckpt_dir, step, like)
            params = state  # params-only checkpoints
            print(f"loaded checkpoint step {step}")

    mesh = None
    if args.sharded:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(model=args.mesh_model)
        print(f"serving mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    fault_cfg = None
    if args.device_backend == "sim_faulty":
        from repro.kernels.backend import FaultConfig

        fault_cfg = FaultConfig(
            seed=args.fault_seed,
            stuck_rate=args.stuck_rate,
            drift_nu=args.drift_nu,
            read_sigma_inflation=args.read_sigma_inflation,
            comparator_offset=args.comparator_offset,
        )
    degradation = None
    if args.degrade:
        from repro.serving import DegradationPolicy

        degradation = DegradationPolicy()

    engine_cls = StaticServingEngine if args.static else ServingEngine
    eng = engine_cls(
        params, cfg,
        ServeConfig(
            max_batch=args.slots,
            max_new_tokens=args.new_tokens,
            max_len=args.max_len,
            kv_layout=args.kv_layout,
            kv_block_size=args.kv_block_size,
            num_kv_blocks=args.kv_blocks,
            enable_prefix_sharing=not args.no_prefix_sharing,
            # passed through verbatim: ServeConfig.validate raises loudly
            # on --kv-layout dense + --prefill-chunk (paged-only knob)
            prefill_chunk=args.prefill_chunk,
            enable_preemption=not args.no_preemption,
            speculate_k=args.speculate_k,
            spill_budget_bytes=args.spill_budget_bytes,
            mesh=mesh,
            device_backend=args.device_backend,
            device_fault_config=fault_cfg,
            canary_interval=args.canary_interval,
            n_redundant_reads=args.n_redundant_reads,
            tile_retire_threshold=args.tile_retire_threshold,
            degradation=degradation,
        ),
    )
    rng = jax.random.PRNGKey(7)
    submit_kw = {}
    if not args.static:
        # the static reference engine has no scheduler: priority and
        # deadline are continuous-engine concepts
        submit_kw = dict(
            priority=args.priority, deadline_ms=args.deadline_ms
        )
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        n = int(jax.random.randint(k, (), 2, 9))
        prompt = jax.random.randint(k, (n,), 0, cfg.vocab).tolist()
        eng.submit(prompt, **submit_kw)
    t0 = time.time()
    # drain everything: the static engine's step() serves only one
    # max_batch wave, so both engines go through their full-drain APIs
    outs = eng.run() if args.static else eng.step()
    dt = time.time() - t0
    m = eng.metrics()
    total = sum(len(o) for o in outs)
    print(
        f"served {len(outs)} requests, {total} tokens in {dt:.2f}s "
        f"({total / max(dt, 1e-9):.1f} tok/s, ttft {m.ttft_mean * 1e3:.0f}ms"
        f" p99 {m.ttft_p99 * 1e3:.0f}ms,"
        f" occupancy {m.occupancy_mean:.2f}, prefix hits {m.prefix_hits},"
        f" partial hits {m.prefix_partial_hits},"
        f" prefill tokens saved {m.prefill_tokens_saved},"
        f" preemptions {m.preemptions} (restores {m.restores}),"
        f" engine={'static' if args.static else 'continuous'}, sampler="
        f"{'WTA votes' if args.wta else 'greedy'})"
    )
    if m.evictions:
        print("evictions:", ", ".join(
            f"{k}={v}" for k, v in sorted(m.evictions.items())
        ))
    if m.spec_rounds:
        print(
            f"speculative: {m.spec_rounds} rounds, "
            f"{m.spec_drafted} drafted / {m.spec_accepted} accepted "
            f"(acceptance {m.spec_acceptance:.2f}, "
            f"{m.spec_tokens_per_round:.2f} tokens/round); "
            f"spill drops {m.spill_drops}"
        )
    for pr, row in sorted(m.latency_by_class.items()):
        print(
            f"class {pr}: n={row['n']} "
            f"ttft p50/p99 {row['ttft_p50_ms']:.0f}/"
            f"{row['ttft_p99_ms']:.0f}ms, "
            f"latency p50/p99 {row['latency_p50_ms']:.0f}/"
            f"{row['latency_p99_ms']:.0f}ms"
        )
    if m.canary_probes or m.degraded_mode or m.degraded_transitions:
        print(
            f"fault tolerance: degraded_mode {m.degraded_mode}, "
            f"canary {m.canary_failures}/{m.canary_probes} failed, "
            f"retired tiles {m.retired_tiles}, "
            f"redundant reads {m.redundant_read_events}, "
            f"transitions {len(m.degraded_transitions)}"
        )
    if m.analog:
        tc = m.analog["tokens_computed"]
        print(
            f"energy (Table I pricing, {m.analog['backend']} backend): "
            f"computed {tc['total']} tokens "
            f"(prefill {tc['prefill']}, decode {tc['decode']}, "
            f"draft {tc['draft']}) for {m.analog['tokens_published']} "
            f"published; "
            f"RACA {m.analog['raca']['energy_pj_per_token']:.0f} pJ/tok "
            f"({m.analog['raca']['tops_per_w_effective']:.2f} TOPS/W), "
            f"1b-ADC {m.analog['adc1b']['energy_pj_per_token']:.0f} pJ/tok "
            f"({m.analog['adc1b']['tops_per_w_effective']:.2f} TOPS/W)"
        )
    for o in outs:
        print("  ->", o)


if __name__ == "__main__":
    main()
