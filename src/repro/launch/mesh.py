"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model") — TPU v5e pod.
Multi-pod: 2×16×16 = 512 chips, axes ("pod", "data", "model"); the "pod"
axis carries only data parallelism (cross-pod traffic = one gradient
all-reduce per step, which is what DCI-connected pods sustain).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int | None = None):
    """``(data, model)`` mesh over locally available devices.

    The serving mesh for tests, benches, and CPU multi-device runs
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  With
    ``data=None`` every local device not consumed by ``model`` goes to
    the data axis; pass ``data`` explicitly to use a subset (e.g. a 1×1
    mesh on a multi-device host for byte-identity checks).
    """
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"model axis {model} does not divide the {n} local devices"
        )
    if data is None:
        data = n // model
    if data < 1 or data * model > n:
        raise ValueError(
            f"mesh ({data}, {model}) needs {data * model} devices, "
            f"have {n}"
        )
    return jax.make_mesh(
        (data, model), ("data", "model"),
        devices=jax.devices()[: data * model],
    )
