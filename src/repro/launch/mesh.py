"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model") — TPU v5e pod.
Multi-pod: 2×16×16 = 512 chips, axes ("pod", "data", "model"); the "pod"
axis carries only data parallelism (cross-pod traffic = one gradient
all-reduce per step, which is what DCI-connected pods sustain).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
