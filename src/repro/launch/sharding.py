"""Sharding policy: logical-axis rules for activations, path rules for
parameters/optimizer state, and cache shardings for serving.

Policy summary (baseline — hillclimbed variants in EXPERIMENTS.md §Perf):
  * batch            → ("pod", "data")  (dropped per-dim when not divisible)
  * heads/ffn/vocab/experts' F dim → "model" (tensor parallelism)
  * params ≥ FSDP_THRESHOLD → largest replicated dim additionally sharded
    over "data" (ZeRO-3); optimizer moments inherit parameter shardings
  * decode KV caches → batch over data; kv_heads over "model" when
    divisible, else the cache *sequence* dim over "model"
  * paged KV pools → the page axis over "data" (pooled capacity scales
    with the data axis at constant per-device memory), kv_heads over
    "model"; scale planes follow their code pages; per-slot state leaves
    keep the dense batch→data rules
Every rule is divisibility-guarded: a mesh axis that does not divide the
dimension is dropped (replicated) rather than relying on GSPMD padding.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

FSDP_THRESHOLD = 8_000_000_000  # params; above this, shard states over data

_FSDP = "__fsdp__"  # placeholder resolved per-mesh/per-shape


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh, batch_size: int) -> Optional[tuple]:
    """Largest prefix of ("pod","data") that divides the batch."""
    sizes = mesh_axis_sizes(mesh)
    axes, prod = [], 1
    for a in ("pod", "data"):
        if a in sizes and batch_size % (prod * sizes[a]) == 0:
            axes.append(a)
            prod *= sizes[a]
    return tuple(axes) or None


def activation_rules(mesh: Mesh, cfg: ModelConfig, batch_size: int) -> dict:
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    return {
        "batch": batch_axes(mesh, batch_size),
        "seq": None,
        "embed": None,
        "heads": "model" if cfg.n_heads % m == 0 else None,
        "kv_heads": "model" if cfg.n_kv_heads % m == 0 else None,
        "ffn": "model",
        "vocab": "model",
        "experts": None,
        "layers": None,
    }


# (path regex, spec template).  _FSDP resolves to "data" (or None) per arch.
_PARAM_RULES = [
    (r"embed/embedding$", ("model", _FSDP)),            # (V, D)
    (r"head/w$", (_FSDP, "model")),                     # (D, V)
    (r"dec_pos$", (None, None)),
    (r"(attn|self_attn|cross_attn)/w[qkv]$", (_FSDP, "model")),
    (r"(attn|self_attn|cross_attn)/wo$", ("model", _FSDP)),
    # MoE rules MUST precede the generic w_up/w_gate/w_down patterns —
    # expert weights carry a leading (E,) axis.
    (r"moe/router$", (_FSDP, None)),
    (r"moe/w_(up|gate)$", (None, _FSDP, "model")),      # (E, D, F)
    (r"moe/w_down$", (None, "model", _FSDP)),           # (E, F, D)
    (r"(ffn|rec)/?w_up$|w_up$", (_FSDP, "model")),
    (r"w_gate$", (_FSDP, "model")),
    (r"w_down$", ("model", _FSDP)),
    (r"mixer/in_proj$", (_FSDP, "model")),
    (r"mixer/out_proj$", ("model", _FSDP)),
    (r"mixer/conv_w$", (None, "model")),
    (r"mixer/conv_b$", ("model",)),
    (r"rec/w_main$|rec/w_gate_br$", (_FSDP, "model")),
    (r"rec/w_out$", ("model", _FSDP)),
    (r"rec/w[ax]$", (_FSDP, "model")),
    (r"rec/conv_w$", (None, "model")),
    (r"rec/conv_b$|rec/b[ax]$|rec/lam$", ("model",)),
]

# MoE weights (E,D,F)/(E,F,D): the rules above keep experts unsharded
# (replicated across model, TP inside the expert).  Hillclimb variant adds
# expert parallelism by mapping the E axis to a mesh axis.


def _spec_for_path(
    path: str,
    shape: tuple,
    mesh: Mesh,
    fsdp: bool,
) -> P:
    sizes = mesh_axis_sizes(mesh)
    ndim = len(shape)
    # scanned-stack prefixes: units/, enc/, dec/ params carry a leading
    # (n_layers-or-units,) axis not covered by the 2-D rule templates.
    n_prefix = 1 if re.match(r"^(units|enc|dec)/", path) else 0
    template: tuple = ()
    for rx, tpl in _PARAM_RULES:
        if re.search(rx, path):
            template = tpl
            break
    template = (None,) * n_prefix + tuple(template)
    template = template + (None,) * (ndim - len(template))
    template = template[:ndim]

    out = []
    used = set()
    for dim, ax in zip(shape, template):
        if ax == _FSDP:
            ax = "data" if fsdp else None
        if ax is None or ax in used or ax not in sizes:
            out.append(None)
            continue
        if dim % sizes[ax] != 0:
            out.append(None)  # divisibility guard: replicate instead of pad
            continue
        used.add(ax)
        out.append(ax)
    return P(*out)


def param_shardings(
    params_sds: Any, mesh: Mesh, cfg: ModelConfig
) -> Any:
    """NamedSharding pytree for a params (or params-shaped) pytree of
    ShapeDtypeStructs."""
    fsdp = (
        cfg.force_fsdp
        if cfg.force_fsdp is not None
        else cfg.param_count() >= FSDP_THRESHOLD
    )
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_sds)
    out = []
    for path, leaf in flat:
        pstr = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        spec = _spec_for_path(pstr, leaf.shape, mesh, fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(state_sds: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    """TrainState shardings: params rules; opt moments inherit; scalars and
    rng replicated; error-feedback buffers inherit param shardings."""
    from repro.train.step import TrainState  # local import, no cycle

    assert isinstance(state_sds, TrainState)
    p_sh = param_shardings(state_sds.params, mesh, cfg)
    rep = NamedSharding(mesh, P())
    opt = state_sds.opt
    opt_sh = type(opt)(
        step=rep,
        m=param_shardings(opt.m, mesh, cfg),
        v=param_shardings(opt.v, mesh, cfg),
    )
    comp_sh = None
    if state_sds.compress is not None:
        comp_sh = type(state_sds.compress)(
            error=param_shardings(state_sds.compress.error, mesh, cfg)
        )
    return TrainState(
        params=p_sh, opt=opt_sh, compress=comp_sh, step=rep, rng=rep
    )


def batch_shardings(batch_sds: dict, mesh: Mesh, batch_size: int) -> dict:
    ax = batch_axes(mesh, batch_size)
    out = {}
    for k, v in batch_sds.items():
        spec = [ax] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_partition_specs(
    cache_sds: dict, mesh: Mesh, cfg: ModelConfig, batch_size: int
) -> dict:
    """PartitionSpec per decode-cache leaf (dense AND paged layouts).

    ``mesh`` only needs ``axis_names`` + ``devices.shape`` (a fake mesh
    works), so the name rules are testable without real devices;
    :func:`cache_shardings` wraps the specs in ``NamedSharding``.

    Dense leaves shard batch over data and kv_heads over "model" (seq
    over "model" as the non-divisible fallback).  Paged-pool leaves
    (``k_pages``/``v_pages``/``k_scale_pages``/``v_scale_pages``,
    shaped ``(nu, n_attn, n_pages, block, Hkv[, Dh])``) shard the PAGE
    axis over "data" — pool capacity grows with the data axis at
    constant per-device memory, which is the serving mesh's scaling
    story — and ``kv_heads`` over "model", each independently guarded:
    a non-divisible dimension replicates instead of padding.  The block
    table stays host-global, so any slot may read any page; GSPMD
    inserts the cross-shard gathers.
    """
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    bax = batch_axes(mesh, batch_size)
    kv_div = cfg.n_kv_heads % m == 0 if cfg.n_kv_heads else False
    out = {}

    def _guard(axes, shape):
        """Drop mesh axes that do not divide their dimension."""
        res = []
        for dim, ax in zip(shape, axes):
            if ax is None:
                res.append(None)
                continue
            sz = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                sz *= sizes.get(a, 1)
            res.append(ax if dim % sz == 0 else None)
        return P(*res)

    for k, v in cache_sds.items():
        nd = len(v.shape)
        if k == "pos":
            spec = P(bax)
        elif k in ("k_pages", "v_pages"):
            # (nu, n_attn, n_pages, block, Hkv, Dh): pages over data,
            # kv_heads over model (replicated when not divisible)
            spec = _guard(
                [None, None, "data", None, "model" if kv_div else None,
                 None],
                v.shape,
            )
        elif k in ("k_scale_pages", "v_scale_pages"):
            # (nu, n_attn, n_pages, block, Hkv): follow the code pages
            spec = _guard(
                [None, None, "data", None, "model" if kv_div else None],
                v.shape,
            )
        elif k in ("k", "v", "ck", "cv"):
            # (..., B, S, Hkv, Dh) with 1-2 leading stack axes
            lead = nd - 4
            seq_ax = None if kv_div else "model"
            spec = _guard(
                ([None] * lead)
                + [bax, seq_ax, "model" if kv_div else None, None],
                v.shape,
            )
        elif k in ("k_scale", "v_scale"):
            # (..., B, S, Hkv): follow the K/V cache layout minus head_dim
            lead = nd - 3
            seq_ax = None if kv_div else "model"
            spec = _guard(
                ([None] * lead) + [bax, seq_ax, "model" if kv_div else None],
                v.shape,
            )
        elif k in ("ssm_conv", "rec_conv"):
            lead = nd - 3
            last = v.shape[-1]
            spec = P(
                *([None] * lead), bax, None,
                "model" if last % m == 0 else None,
            )
        elif k == "ssm_state":
            # (nu, n, B, H, P, N): shard heads over model
            h = v.shape[-3]
            spec = P(
                None, None, bax, "model" if h % m == 0 else None, None, None
            )
        elif k == "rec_h":
            w = v.shape[-1]
            spec = P(None, None, bax, "model" if w % m == 0 else None)
        else:
            # quant_step (scalar) and any future bookkeeping leaves
            spec = P(*([None] * nd))
        out[k] = spec
    return out


def cache_shardings(
    cache_sds: dict, mesh: Mesh, cfg: ModelConfig, batch_size: int
) -> dict:
    """NamedSharding per decode-cache leaf; see :func:`cache_partition_specs`
    for the name rules (this wrapper needs a real device mesh)."""
    return {
        k: NamedSharding(mesh, spec)
        for k, spec in cache_partition_specs(
            cache_sds, mesh, cfg, batch_size
        ).items()
    }
