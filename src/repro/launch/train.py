"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
        --steps 50 --batch 8 --seq 128 [--analog] [--compress] [--model-par 1]

Runs the fault-tolerant loop (checkpoints, auto-resume, straggler monitor)
on the locally visible devices with the production sharding rules — the
same code path the multi-pod dry-run lowers, at whatever scale the host
provides (elastic: restart with any device count and the checkpoint
re-shards).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax

from repro import parallel
from repro.configs import get_config, get_smoke_config
from repro.core.analog import AnalogConfig
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.data import lm_batch, mnist_batch
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.loop import LoopConfig, run

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    ap.add_argument("--analog", action="store_true",
                    help="RACA analog-stochastic execution (QAT)")
    ap.add_argument("--model-par", type=int, default=1,
                    help="model-parallel size on the host mesh")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.analog:
        cfg = dataclasses.replace(
            cfg,
            analog=AnalogConfig(
                mode="analog_stochastic",
                device=calibrate_v_read(DeviceParams(), cfg.d_model),
                use_pallas="auto",
            ),
        )
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        compress_grads=args.compress,
        total_steps=args.steps,
    )
    lcfg = LoopConfig(
        steps=args.steps,
        ckpt_dir=args.ckpt_dir or f"ckpts/{cfg.name}",
        ckpt_every=max(args.steps // 4, 1),
        log_every=10,
    )

    mesh = make_host_mesh(model=args.model_par)
    rules = SH.activation_rules(mesh, cfg, args.batch)
    with parallel.axis_rules(mesh, rules):
        state_sds = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(lcfg.seed), cfg, tcfg)
        )
        state_sh = SH.state_shardings(state_sds, mesh, cfg)
        step_fn = jax.jit(
            make_train_step(cfg, tcfg),
            in_shardings=(state_sh, None),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )

        if cfg.family == "fcnn":
            batch_fn = lambda s: mnist_batch(batch=args.batch, step=s)
        else:
            batch_fn = lambda s: lm_batch(
                cfg, batch=args.batch, seq=args.seq, step=s
            )
        state, stats = run(
            cfg, tcfg, lcfg, batch_fn,
            state_shardings=state_sh, step_fn=step_fn,
        )
    losses = stats["losses"]
    if losses:
        print(
            f"done: steps={int(state.step)} first_loss={losses[0][1]:.4f} "
            f"last_loss={losses[-1][1]:.4f} restarts={stats['restarts']} "
            f"stragglers={stats['stragglers']}"
        )


if __name__ == "__main__":
    main()
