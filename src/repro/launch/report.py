"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from runs/dryrun.json.

    PYTHONPATH=src python -m repro.launch.report runs/dryrun.json
"""

from __future__ import annotations

import json
import sys

from repro.configs import ASSIGNED_ARCHS, SHAPES
from repro.launch import roofline as RL


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PB"


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def dryrun_table(results: dict) -> str:
    """§Dry-run: per cell × mesh — compile ok, per-device memory."""
    lines = [
        "| arch | shape | mesh | compile | HBM/dev (args+temp) | fits 16G |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            skip_key = f"{arch}|{shape}|skipped"
            if skip_key in results:
                lines.append(
                    f"| {arch} | {shape} | - | SKIP | "
                    f"{results[skip_key]['skipped'][:46]} | - |"
                )
                continue
            for mesh in ("single", "multi"):
                key = f"{arch}|{shape}|{mesh}"
                r = results.get(key)
                if r is None:
                    continue
                if "error" in r:
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | **FAIL** | "
                        f"{r['error'][:46]} | - |"
                    )
                    continue
                mem = r.get("memory", {})
                hbm = (
                    mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0)
                )
                fits = "yes" if hbm <= RL.HBM_PER_CHIP else "**no**"
                lines.append(
                    f"| {arch} | {shape} | {mesh} | "
                    f"{r.get('compile_s', '-')}s | {fmt_bytes(hbm)} | {fits} |"
                )
    return "\n".join(lines)


def roofline_table(results: dict, tag: str = "") -> str:
    """§Roofline: single-pod terms per cell."""
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPs/HLO_FLOPs | roofline frac | what would move it |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            key = f"{arch}|{shape}|single" + (f"|{tag}" if tag else "")
            r = results.get(key)
            if r is None or "error" in r or "roofline" not in r:
                continue
            ro = RL.roofline_terms(r)
            hint = RL.improvement_hint(r, ro)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(ro['compute_s'])} | "
                f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
                f"{ro['dominant']} | {ro['useful_flops_ratio']:.3f} | "
                f"{ro['roofline_fraction']:.3f} | {hint} |"
            )
    return "\n".join(lines)


def collective_table(results: dict) -> str:
    lines = [
        "| arch | shape | all-reduce | all-gather | reduce-scatter | "
        "all-to-all | permute | #ops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            r = results.get(f"{arch}|{shape}|single")
            if not r or "collectives" not in r:
                continue
            c = r["collectives"]
            lines.append(
                f"| {arch} | {shape} | {fmt_bytes(c['all-reduce'])} | "
                f"{fmt_bytes(c['all-gather'])} | "
                f"{fmt_bytes(c['reduce-scatter'])} | "
                f"{fmt_bytes(c['all-to-all'])} | "
                f"{fmt_bytes(c['collective-permute'])} | {int(c['count'])} |"
            )
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    done = sum(1 for v in results.values()
               if "error" not in v and "skipped" not in v)
    failed = {k: v["error"] for k, v in results.items() if "error" in v}
    print(f"## cells ok: {done}; failed: {len(failed)}\n")
    for k, e in failed.items():
        print(f"FAILED {k}: {e}")
    print("\n### Dry-run\n")
    print(dryrun_table(results))
    print("\n### Roofline (single-pod, per device)\n")
    print(roofline_table(results))
    print("\n### Collectives (single-pod, per device per step)\n")
    print(collective_table(results))


if __name__ == "__main__":
    main()
