import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
        --shape train_4k --mesh multi --out runs/dryrun.json

Results accumulate into a JSON keyed "arch|shape|mesh"; launch/report.py
renders EXPERIMENTS.md tables from it.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import parallel
from repro.configs import SHAPES, cells, get_config, skip_shapes
from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.train import TrainConfig, make_train_step


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ]
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {
        k: float(v)
        for k, v in ca.items()
        if isinstance(v, (int, float)) and (
            k in ("flops", "transcendentals", "bytes accessed")
            or k.startswith("bytes accessed")
        )
    }


PROD_TRAIN_MICROBATCHES = 4  # grad accumulation in the production pass


def _compile_cell(cfg, shape, mesh, tcfg: TrainConfig):
    """Lower + compile the appropriate step for one cell; returns compiled."""
    rules = SH.activation_rules(mesh, cfg, shape.global_batch)
    with parallel.axis_rules(mesh, rules):
        if shape.kind == "train":
            state_sds = SP.train_state_specs(cfg, tcfg)
            state_sh = SH.state_shardings(state_sds, mesh, cfg)
            batch_sds = SP.train_batch_specs(cfg, shape)
            batch_sh = SH.batch_shardings(batch_sds, mesh, shape.global_batch)
            step = make_train_step(cfg, tcfg)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_sds = SP.params_specs(cfg)
            params_sh = SH.param_shardings(params_sds, mesh, cfg)
            batch_sds = SP.prefill_batch_specs(cfg, shape)
            batch_sh = SH.batch_shardings(batch_sds, mesh, shape.global_batch)
            step = SP.make_prefill_step(cfg, shape)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            params_sds = SP.params_specs(cfg)
            params_sh = SH.param_shardings(params_sds, mesh, cfg)
            cache_sds = SP.decode_cache_specs(cfg, shape)
            cache_sh = SH.cache_shardings(
                cache_sds, mesh, cfg, shape.global_batch
            )
            bax = SH.batch_axes(mesh, shape.global_batch)
            tok_sh = NamedSharding(mesh, P(bax))
            step = SP.make_serve_step(cfg)
            tok_sds = SP._sds((shape.global_batch,), jnp.int32)
            if cfg.wta_head:
                # WTA stochastic sampling head needs a PRNG key input
                key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, cache_sh, tok_sh, None),
                    out_shardings=(cache_sh, tok_sh),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(
                    params_sds, cache_sds, tok_sds, key_sds
                )
            else:
                jitted = jax.jit(
                    step,
                    in_shardings=(params_sh, cache_sh, tok_sh),
                    out_shardings=(cache_sh, tok_sh),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(params_sds, cache_sds, tok_sds)
        return lowered.compile()


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    cfg_overrides: dict | None = None,
    save_hlo: str | None = None,
    passes: str = "both",  # prod | cost | both
    microbatches: int | None = None,
) -> dict:
    """Two compiles per cell:

    * production pass — scan-over-layers + grad microbatching, exactly what
      a real deployment runs: proves compile + records memory_analysis.
    * cost pass — cost_exact=True (all scans unrolled, microbatches=1) so
      cost_analysis and the HLO collective parse count EVERY loop iteration
      (XLA counts a while-loop body once); feeds §Roofline.
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg_overrides = dict(cfg_overrides)
        analog_mode = cfg_overrides.pop("analog_mode", None)
        if analog_mode:
            from repro.core.physics import DeviceParams, calibrate_v_read

            acfg = dataclasses.replace(
                cfg.analog.with_mode(analog_mode),
                device=calibrate_v_read(DeviceParams(), cfg.d_model),
                use_pallas="off",  # jnp path inside the SPMD compile
            )
            cfg_overrides["analog"] = acfg
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "overrides": {k: str(v) for k, v in (cfg_overrides or {}).items()},
    }

    if passes in ("prod", "both"):
        t0 = time.time()
        mb = microbatches or PROD_TRAIN_MICROBATCHES
        rec["prod_microbatches"] = mb
        compiled = _compile_cell(
            cfg, shape, mesh, TrainConfig(microbatches=mb),
        )
        rec["compile_s"] = round(time.time() - t0, 2)
        rec["memory"] = _mem_analysis(compiled)
        del compiled

    if passes in ("cost", "both"):
        t1 = time.time()
        rec["cost"], rec["collectives"] = _exact_cost(
            cfg, shape, mesh, save_hlo
        )
        rec["cost_compile_s"] = round(time.time() - t1, 2)
        rec["model_flops_global"] = RL.model_flops(cfg, shape)
        rec["model_flops_per_chip"] = rec["model_flops_global"] / n_chips
        rec["roofline"] = RL.roofline_terms(rec)
    return rec


def _with_units(cfg, k: int):
    """Config with k repeating units (layer stack reduced), full-model
    sharding policy pinned."""
    fsdp = cfg.param_count() >= SH.FSDP_THRESHOLD
    kw = dict(cost_exact=True, force_fsdp=fsdp)
    if cfg.family == "encdec":
        kw.update(enc_layers=k, dec_layers=k, n_layers=2 * k)
    else:
        kw.update(n_layers=k * len(cfg.layer_pattern))
    return dataclasses.replace(cfg, **kw)


def _n_units_of(cfg) -> int:
    return cfg.enc_layers if cfg.family == "encdec" else cfg.n_units


def _exact_cost(cfg, shape, mesh, save_hlo=None):
    """Exact per-step cost via unit differencing.

    XLA counts a while-loop body once, so the roofline pass unrolls every
    scan (cost_exact).  Full unrolls compile slowly, so instead we compile
    1-unit and 2-unit versions (identical HLO per unit after GSPMD) and
    extrapolate linearly: cost(n) = cost(1) + (n-1)·(cost(2) - cost(1)).
    Exact for identical scanned units; embed/logits/optimizer terms live in
    the base.  fcnn-like flat models compile directly.
    """
    n_units = _n_units_of(cfg)
    if cfg.family == "fcnn" or n_units <= 2:
        compiled = _compile_cell(
            dataclasses.replace(cfg, cost_exact=True), shape, mesh,
            TrainConfig(),
        )
        cost = _cost_analysis(compiled)
        colls = RL.parse_collectives(compiled.as_text())
        return cost, colls

    c1 = _compile_cell(_with_units(cfg, 1), shape, mesh, TrainConfig())
    cost1 = _cost_analysis(c1)
    coll1 = RL.parse_collectives(c1.as_text())
    del c1
    c2 = _compile_cell(_with_units(cfg, 2), shape, mesh, TrainConfig())
    cost2 = _cost_analysis(c2)
    coll2 = RL.parse_collectives(c2.as_text())
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(c2.as_text())
    del c2

    def extrap(d1, d2):
        out = {}
        for k in set(d1) | set(d2):
            a, b = d1.get(k, 0.0), d2.get(k, 0.0)
            if isinstance(a, str) or isinstance(b, str):
                continue
            out[k] = a + (n_units - 1) * (b - a)
        return out

    cost = extrap(cost1, cost2)
    colls = extrap(coll1, coll2)
    cost["extrapolated_from_units"] = 2.0
    return cost, colls


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun.json")
    ap.add_argument("--save-hlo")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--tag", default="", help="suffix for result keys")
    ap.add_argument("--passes", choices=["prod", "cost", "both"],
                    default="both")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="grad-accumulation microbatches for the prod pass")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    if args.all:
        todo = [(a, s) for a, s, skip in cells() if skip is None]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape in todo:
        skips = skip_shapes(arch)
        if shape in skips:
            key = f"{arch}|{shape}|skipped"
            results[key] = {"skipped": skips[shape]}
            print(f"[skip] {arch} × {shape}: {skips[shape]}", flush=True)
            continue
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            key = f"{arch}|{shape}|{mesh_name}" + (
                f"|{args.tag}" if args.tag else ""
            )
            if key in results and "error" not in results[key] and not overrides:
                print(f"[cached] {key}", flush=True)
                continue
            print(f"[run] {key} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp, overrides or None,
                               args.save_hlo, passes=args.passes,
                               microbatches=args.microbatches)
                results[key] = rec
                msg = f"  ok compile={rec.get('compile_s')}s"
                if "roofline" in rec:
                    r = rec["roofline"]
                    msg += (
                        f" cost_compile={rec.get('cost_compile_s')}s"
                        f" compute={r['compute_s']:.3e}s"
                        f" memory={r['memory_s']:.3e}s"
                        f" coll={r['collective_s']:.3e}s dom={r['dominant']}"
                        f" frac={r['roofline_fraction']:.3f}"
                    )
                print(msg, flush=True)
            except Exception as e:
                results[key] = {
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print("done.", flush=True)


if __name__ == "__main__":
    main()
