"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

`input_specs(arch, shape)` returns weak-type-correct, shardable specs with
no device allocation, for the step function the shape's kind lowers:
  train   → train_step(state, batch)
  prefill → prefill_step(params, batch)
  decode  → serve_step(params, cache, token)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.models import ModelConfig, get_model_fns
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.train import TrainConfig, TrainState, init_train_state

WHISPER_DEC_PROMPT = 448  # decoder prompt length for encdec prefill cells

_i32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), _i32), "labels": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, s, cfg.d_model), cfg.dtype)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # prefill_32k for whisper = encode S frames + short decoder prompt
        return {
            "frames": _sds((b, s, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, WHISPER_DEC_PROMPT), _i32),
        }
    out = {"tokens": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    return out


def decode_cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: ED.init_encdec_cache(cfg, b, s, cfg.enc_seq)
        )
    return jax.eval_shape(lambda: TF.init_decode_cache(cfg, b, s))


def params_specs(cfg: ModelConfig) -> Any:
    fns = get_model_fns(cfg)
    return jax.eval_shape(lambda k: fns.init(k, cfg), jax.random.PRNGKey(0))


def train_state_specs(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0)
    )


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token(B,)) -> (cache, token).

    With cfg.wta_head the next token comes from the paper's WTA stochastic
    SoftMax circuit (vote counts over noisy comparator trials) instead of a
    digital argmax — the serving-side integration of the technique."""
    fns = get_model_fns(cfg)

    def serve_step(params, cache, token, key=None):
        cache, logits = fns.decode_step(params, cache, token, cfg)
        if cfg.wta_head and key is not None:
            from repro.core import wta as W

            res = W.wta_trials(
                key,
                logits.astype(jnp.float32),
                n_trials=cfg.analog.wta_trials,
                vth0=cfg.analog.vth0,
                beta=cfg.analog.beta,
            )
            nxt = jnp.argmax(res.counts, axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return cache, nxt

    return serve_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec):
    fns = get_model_fns(cfg)
    max_len = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)

    def prefill_step(params, batch):
        return fns.prefill(params, batch, cfg, max_len)

    return prefill_step


def input_specs(arch: str, shape_name: str, tcfg: TrainConfig | None = None):
    """The dry-run entry: (step_fn_kind, arg specs) for an (arch, shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        return {
            "kind": "train",
            "cfg": cfg,
            "shape": shape,
            "state": train_state_specs(cfg, tcfg),
            "batch": train_batch_specs(cfg, shape),
            "tcfg": tcfg,
        }
    if shape.kind == "prefill":
        return {
            "kind": "prefill",
            "cfg": cfg,
            "shape": shape,
            "params": params_specs(cfg),
            "batch": prefill_batch_specs(cfg, shape),
        }
    return {
        "kind": "decode",
        "cfg": cfg,
        "shape": shape,
        "params": params_specs(cfg),
        "cache": decode_cache_specs(cfg, shape),
        "token": _sds((shape.global_batch,), _i32),
    }
