"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

`input_specs(arch, shape)` returns weak-type-correct, shardable specs with
no device allocation, for the step function the shape's kind lowers:
  train   → train_step(state, batch)
  prefill → prefill_step(params, batch)
  decode  → serve_step(params, cache, token)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.models import ModelConfig, get_model_fns
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.train import TrainConfig, TrainState, init_train_state

WHISPER_DEC_PROMPT = 448  # decoder prompt length for encdec prefill cells

_i32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), _i32), "labels": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, s, cfg.d_model), cfg.dtype)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # prefill_32k for whisper = encode S frames + short decoder prompt
        return {
            "frames": _sds((b, s, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, WHISPER_DEC_PROMPT), _i32),
        }
    out = {"tokens": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    return out


def decode_cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: ED.init_encdec_cache(cfg, b, s, cfg.enc_seq)
        )
    return jax.eval_shape(lambda: TF.init_decode_cache(cfg, b, s))


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Concrete (allocated) decode cache for a live serving batch."""
    if cfg.family == "encdec":
        return ED.init_encdec_cache(cfg, batch, max_len, cfg.enc_seq)
    return TF.init_decode_cache(cfg, batch, max_len)


def init_paged_decode_cache(
    cfg: ModelConfig, batch: int, n_pages: int, block_size: int
) -> dict:
    """Concrete paged decode cache (shared block pool + per-slot states)."""
    if cfg.family == "encdec":
        raise ValueError("paged KV cache is token-LM only (no encdec)")
    return TF.init_paged_decode_cache(cfg, batch, n_pages, block_size)


def paged_decode_cache_specs(
    cfg: ModelConfig, batch: int, n_pages: int, block_size: int
) -> dict:
    return jax.eval_shape(
        lambda: init_paged_decode_cache(cfg, batch, n_pages, block_size)
    )


def cache_batch_axis(cfg: ModelConfig, leaf_name: str) -> int:
    """Which axis of a decode-cache leaf is the request/slot axis.

    ``pos`` is (B,); LM-family leaves are (n_units, n_per_unit, B, ...);
    encdec leaves are (n_layers, B, ...) — the layout contract that
    slot-addressable insertion below relies on.  Covered by
    tests/test_specs.py so cache-layout refactors fail loudly.
    """
    if leaf_name == "pos":
        return 0
    return 1 if cfg.family == "encdec" else 2


def make_cache_insert(cfg: ModelConfig):
    """Insert one request's prefill cache into a live batch cache at ``slot``.

    (batch_cache, one_cache(B=1), slot int32) -> batch_cache.  The slot index
    is a traced scalar, so one jit of this function serves every slot of a
    live batch without recompiling — the continuous-batching refill path.
    """

    def insert(batch_cache: dict, one_cache: dict, slot) -> dict:
        out = {}
        for name, leaf in batch_cache.items():
            upd = one_cache[name].astype(leaf.dtype)
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                leaf, upd, slot, axis=cache_batch_axis(cfg, name)
            )
        return out

    return insert


def init_prefill_state(cfg: ModelConfig) -> dict:
    """Zeroed B=1 state leaves entering a chunked paged prefill."""
    return TF.init_prefill_state(cfg)


def make_paged_suffix_prefill(cfg: ModelConfig):
    """One suffix chunk of a resumable, chunked paged prefill.

    (params, paged_cache, state{B=1}, tokens (1, c) int32, table_row
    (Wp,) int32, q0 int32 [, quant_seeds (nbc,) uint32], *, bucket) →
    (paged_cache, state', last-token logits (1, V)).

    THE paged prefill entry point — it subsumes the old monolithic
    per-request prefill + block scatter: a cold admission runs its whole
    bucket as chunks from zeroed state (:func:`init_prefill_state`), a
    partial-prefix hit runs only the suffix (``q0 > 0``) attending into
    the shared pages already mapped in ``table_row``, and the engine
    interleaves at most ``ServeConfig.prefill_chunk`` tokens per tick
    between decode steps.  Only the page-pool leaves of ``paged_cache``
    are touched — the per-slot leaves ride along untouched, so a chunked
    prefill in flight is never corrupted by the batched decode steps
    running for the OTHER slots (the engine threads ``state`` host-side
    and writes it at the slot once, on completion).

    With ``all_logits=True`` (static, default off) the logits output is
    ``(1, c, V)`` — one next-token row per chunk position, the
    multi-token-logits variant that makes a k-token chunk a one-call
    verifier over k decode positions (the cross-path oracle the
    speculative-decoding tests pin against the decode-cell verifier).

    Compile discipline: ``bucket`` is the only routinely-varying static
    argument (the attention window slice), so compiles are one per
    (bucket, chunk shape) pair; page ids, the start position, and the
    int8 rounding seeds are all traced.  int8 pools quantize each chunk block under its
    content-derived seed (chain hash → uint32, folded with the unit and
    sublayer index inside) — the canonical-seed contract that keeps
    shared int8 blocks bit-identical across writers.
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def suffix_chunk(
        params, cache: dict, state: dict, tokens, table_row, q0,
        quant_seeds=None, *, bucket: int, all_logits: bool = False,
    ):
        pool = {n: cache[n] for n in PAGE_POOL_LEAVES if n in cache}
        new_pool, new_state, logits = TF.lm_prefill_chunk(
            params, tokens, cfg, pool, state, table_row, q0, bucket,
            quant_seeds, all_logits=all_logits,
        )
        out = dict(cache)
        out.update(new_pool)
        return out, new_state, logits

    return suffix_chunk


# page-pool cache leaves (vs the dense per-slot leaves) — the split that
# prefix sharing relies on: pool leaves are mapped through block tables and
# may be shared across slots, per-slot leaves are always private
PAGE_POOL_LEAVES = (
    "k_pages", "v_pages", "k_scale_pages", "v_scale_pages"
)


def _spec_state_leaves(cache: dict) -> dict:
    """The per-slot leaves a speculative round snapshots / rolls back:
    everything except the shared page pool and the engine-wide int8
    ``quant_step`` counter (rewinding that would replay rounding draws)."""
    return {
        n: v for n, v in cache.items()
        if n not in PAGE_POOL_LEAVES and n != "quant_step"
    }


def make_paged_spec_round(cfg: ModelConfig, k: int):
    """One fused draft-k → verify-k speculative round over a paged cache.

    (params, cache, table (B, W), token (B,), keys (B, 2), steps (B,)) →
    (cache, dtoks (B, k), doks (B, k), vtoks (B, k), voks (B, k),
    vstates {state leaf: (k, ...)}).

    Draft phase: a ``lax.scan`` of ``k`` chained batched decode steps —
    each scan cell IS :func:`TF.lm_decode_step` + :func:`sample_tokens`
    with the slot's own ``(key, steps + j)``, so the drafted chain is
    bit-identical to ``k`` plain engine ticks (the greedy byte-identity
    contract holds by construction, not by tolerance).  Drafted K/V lands
    in the slots' reserved pages as it would under plain decode; the int8
    pool's ``quant_step`` advances one per draft step, exactly like the
    plain path.

    Verify phase: a read-only re-decode of the whole drafted run in the
    SAME dispatch — every (slot, step) pair verified in parallel as ONE
    ``k·B``-row batched decode call (the multi-token-logits pass).  Row
    ``(j, s)`` consumes input ``j`` of ``[token, dtoks[:-1]]`` for slot
    ``s`` at absolute position ``q0_s + j`` with ``kv_write=False``:
    identical per-row math attending the pages the draft just wrote
    (per-row decode logits are batch-size-invariant bitwise — the same
    property the batch-composition-invariance contract pins), resampled
    with the same ``(key, steps + j)`` the draft used.  In a fault-free
    run ``vtoks == dtoks`` bitwise and every draft accepts; when a draft
    diverged (noisy analog drafter, injected fault), the first mismatch
    index is simultaneously the rejection point AND the corrected
    resample.  ``vstates[leaf][j]`` — the per-slot state after consuming
    input ``j``, emitted by the draft scan (the verifier consumes the
    drafts themselves, so draft and verify states coincide bitwise on
    every row, matched or not) — is the rollback target for
    :func:`make_spec_rollback`.

    The wall-clock shape is the point: ``k`` sequential unit evals
    (draft, irreducibly autoregressive) plus ONE parallel verify eval
    per round, against ``k`` sequential evals plus ``k`` full host
    round-trips for the plain path — per-tick host overhead amortizes
    over the accepted run.

    ``doks``/``voks`` are the per-step finite-logits flags (the NaN guard
    at draft depth): the engine truncates a slot's usable drafts at the
    first non-finite draft step.  One compile per (window W, k) pair —
    same power-of-two window bucketing as the plain serve step.
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")
    if k < 1:
        raise ValueError(f"speculate_k must be >= 1, got {k}")

    def spec_round(params, cache, table, token, keys, steps):
        snap = _spec_state_leaves(cache)

        def draft(carry, j):
            cch, tok = carry
            cch, logits = TF.lm_decode_step(params, cch, tok, cfg, table)
            nxt = sample_tokens(cfg, logits, keys, steps + j)
            ok = jnp.isfinite(logits.astype(jnp.float32)).all(axis=-1)
            return (cch, nxt), (nxt, ok, _spec_state_leaves(cch))

        (cache, _), (dtoks, doks, vstates) = jax.lax.scan(
            draft, (cache, token), jnp.arange(k, dtype=_i32)
        )

        # expanded-batch verify view: row (j, s) = slot s about to consume
        # input j, so its state is S_j (pre-draft snapshot for j=0, the
        # draft scan's post-step state otherwise)
        view = {n: cache[n] for n in cache if n not in vstates}
        for name, st in vstates.items():
            ax = cache_batch_axis(cfg, name)
            pre = jnp.concatenate([snap[name][None], st[:-1]], axis=0)
            arr = jnp.moveaxis(pre, 0, ax)  # (..., k, B, ...)
            view[name] = arr.reshape(
                arr.shape[:ax] + (-1,) + arr.shape[ax + 2:]
            )
        inputs = jnp.concatenate([token[None], dtoks[:-1]], axis=0)  # (k, B)
        xkeys = jnp.tile(keys, (k, 1))
        xsteps = (
            jnp.tile(steps, (k,))
            + jnp.repeat(jnp.arange(k, dtype=steps.dtype), steps.shape[0])
        )
        _, logits = TF.lm_decode_step(
            params, view, inputs.reshape(-1), cfg,
            jnp.tile(table, (k, 1)), kv_write=False,
        )
        vtoks = sample_tokens(cfg, logits, xkeys, xsteps).reshape(inputs.shape)
        voks = (
            jnp.isfinite(logits.astype(jnp.float32))
            .all(axis=-1).reshape(inputs.shape)
        )
        return cache, dtoks.T, doks.T, vtoks.T, voks.T, vstates

    return spec_round


def make_spec_rollback(cfg: ModelConfig):
    """Roll ONE slot back to the post-acceptance state of a rejected round.

    (paged_cache, vstates {leaf: (k, ...)}, idx int32, slot int32) →
    paged_cache.  ``vstates[leaf][idx]`` is the round's per-slot state
    after consuming the last accepted input (the verify inputs ARE the
    drafts, so the draft scan's post-step states are bitwise the states a
    plain engine would hold at that point; ``pos`` included, so the
    slot's position rewinds with its recurrent/SSM state in one shot).  Drafted
    K/V beyond the rollback position stays in the pages as dead rows:
    positions ≥ ``pos`` are masked to exact-zero attention weight and the
    rows are overwritten verbatim when decode reaches them again.  Both
    ``idx`` and ``slot`` are traced — ONE compile per engine lifetime
    (shapes are fixed by ``k``).
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def rollback(cache: dict, vstates: dict, idx, slot) -> dict:
        out = dict(cache)
        for name, st in vstates.items():
            leaf = cache[name]
            ax = cache_batch_axis(cfg, name)
            row = jax.lax.dynamic_index_in_dim(st, idx, axis=0, keepdims=False)
            row = jax.lax.dynamic_slice_in_dim(row, slot, 1, axis=ax)
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                leaf, row.astype(leaf.dtype), slot, axis=ax
            )
        return out

    return rollback


def make_paged_state_insert(cfg: ModelConfig):
    """Insert only the dense per-slot leaves of a one-request cache.

    (paged_cache, state_leaves{B=1}, slot int32) → paged_cache.  The
    prefix-sharing full-hit admission path: when every block covering a
    request's padded prompt is already resident (matched through the
    allocator's content-hash index), the engine maps the shared pages into
    the slot's table row and skips the prefill — but the per-slot leaves
    (``pos``, recurrent/SSM states) still need the stored values from the
    original prefill.  ``state_leaves`` holds exactly those leaves (no
    ``k``/``v``); their shapes are bucket-independent, so this compiles
    ONCE for the engine's whole lifetime.
    """

    def insert(batch_cache: dict, state_leaves: dict, slot) -> dict:
        out = dict(batch_cache)
        for name, upd in state_leaves.items():
            leaf = batch_cache[name]
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                leaf, upd.astype(leaf.dtype), slot,
                axis=cache_batch_axis(cfg, name),
            )
        return out

    return insert


def make_page_copy(cfg: ModelConfig):
    """Copy one pool page onto another across every page-pool leaf.

    (paged_cache, src int32, dst int32) → paged_cache.  The device half of
    a copy-on-write fork: the engine repoints the writer's table row at
    ``dst`` and the batched decode step then writes there, while the other
    owners keep reading the pristine ``src``.  int8 pools copy the scale
    planes alongside the code pages.  Page ids are traced — one compile
    serves every fork.
    """

    def copy(cache: dict, src, dst) -> dict:
        out = dict(cache)
        for name in PAGE_POOL_LEAVES:
            if name in cache:
                leaf = cache[name]  # (nu, n_attn, P, bs, ...)
                out[name] = leaf.at[:, :, dst].set(leaf[:, :, src])
        return out

    return copy


def paged_cache_shardings(
    cfg: ModelConfig, mesh, batch: int, n_pages: int, block_size: int
) -> dict:
    """NamedSharding per paged-cache leaf under ``mesh`` (page axis over
    "data", kv_heads over "model", per-slot leaves batch over "data" —
    every rule divisibility-guarded; see sharding.cache_partition_specs)."""
    from repro.launch import sharding as SH

    sds = paged_decode_cache_specs(cfg, batch, n_pages, block_size)
    return SH.cache_shardings(sds, mesh, cfg, batch)


def make_sharded_paged_entry_points(
    cfg: ModelConfig, mesh, *, batch: int, n_pages: int, block_size: int,
    speculate_k: int = 0, n_redundant: int = 1, sat_threshold: float = 1e6,
    entropy_floor: float = 0.0,
) -> dict:
    """The paged serving entry points, jitted mesh-aware.

    Each of the four device entry points the paged engine drives —
    :func:`make_paged_serve_step`, :func:`make_paged_suffix_prefill`,
    :func:`make_paged_state_insert`, :func:`make_page_copy` — gains
    ``in_shardings``/``out_shardings`` (``jax.jit`` + ``NamedSharding``)
    over a ``(data, model)`` mesh:

      * the paged pool shards its PAGE axis over ``data`` and ``kv_heads``
        over ``model`` (divisibility-guarded — a non-divisible dim
        replicates), so pool capacity scales with the data axis at
        constant per-device memory;
      * per-slot decode inputs — block table ``(B, W)``, tokens ``(B,)``,
        per-slot keys ``(B, 2)``, step counters ``(B,)`` — shard their
        slot axis over ``data`` (guarded on ``B``);
      * params are REPLICATED across the serving mesh: decode is
        memory-bound on the KV pool, and replicated weights keep every
        reduction order identical to the single-device engine (the
        byte-identity contract on a 1×1 mesh, token identity on wider
        meshes);
      * B=1 prefill-side arguments (suffix-chunk tokens, threaded state,
        table row, q0, quant seeds) and the chunk logits are replicated —
        one request's chunk is not worth sharding.

    The block table, ``BlockAllocator``, and the content-hash prefix
    index stay HOST-GLOBAL: any slot may map any page, so prefix sharing
    and copy-on-write work across shards unchanged; GSPMD inserts the
    cross-shard page gathers.

    Donation and compile discipline match the unsharded entry points
    (cache donated everywhere; ``bucket`` the only static argument of the
    suffix prefill), so the engine's recompile guards hold verbatim.

    Returns ``{"serve_step", "suffix_prefill", "state_insert",
    "page_copy", "page_spill", "page_restore", "state_gather",
    "shardings"}`` where ``shardings`` maps
    ``params/cache/table/slot_vec/slot_keys/replicated`` to the
    NamedShardings used — the engine places its host→device transfers
    (``jax.device_put``) with exactly these.  With ``speculate_k > 0``
    the dict also carries ``spec_round`` / ``spec_rollback``
    (:func:`make_paged_spec_round` / :func:`make_spec_rollback`): the
    round's per-slot inputs shard like the serve step's, the stacked
    per-step verifier states shard like their cache leaves with a
    replicated leading step axis, and the rollback donates the cache like
    every other admission-time mutation.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch import sharding as SH

    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")
    cache_sh = paged_cache_shardings(cfg, mesh, batch, n_pages, block_size)
    rep = NamedSharding(mesh, PartitionSpec())
    params_sh = jax.tree_util.tree_map(lambda _: rep, params_specs(cfg))
    bax = SH.batch_axes(mesh, batch)
    vec_sh = NamedSharding(mesh, PartitionSpec(bax))
    mat_sh = NamedSharding(mesh, PartitionSpec(bax, None))
    serve_step = jax.jit(
        make_paged_serve_step(
            cfg, n_redundant=n_redundant, sat_threshold=sat_threshold,
            entropy_floor=entropy_floor,
        ),
        donate_argnums=(1,),
        in_shardings=(params_sh, cache_sh, mat_sh, vec_sh, mat_sh, vec_sh),
        out_shardings=(cache_sh, vec_sh, vec_sh),
    )
    # (params, cache, state, tokens, table_row, q0[, quant_seeds])
    prefill_in = [params_sh, cache_sh, rep, rep, rep, rep]
    if cfg.kv_cache_dtype == "int8":
        prefill_in.append(rep)
    # pjit rejects kwargs once in_shardings is given, so the static
    # ``bucket`` rides as the LAST positional arg here; the thin kwarg
    # shim below keeps the engine's ``(*args, bucket=...)`` call site
    # layout-agnostic.  in_shardings covers only the dynamic args.
    base_prefill = make_paged_suffix_prefill(cfg)

    def _prefill_pos(*args):
        return base_prefill(*args[:-1], bucket=args[-1])

    prefill_jit = jax.jit(
        _prefill_pos,
        static_argnums=(len(prefill_in),),
        donate_argnums=(1,),
        in_shardings=tuple(prefill_in),
        out_shardings=(cache_sh, rep, rep),
    )

    def suffix_prefill(*args, bucket):
        return prefill_jit(*args, bucket)

    suffix_prefill._cache_size = prefill_jit._cache_size
    state_insert = jax.jit(
        make_paged_state_insert(cfg),
        donate_argnums=(0,),
        in_shardings=(cache_sh, rep, rep),
        out_shardings=cache_sh,
    )
    page_copy = jax.jit(
        make_page_copy(cfg),
        donate_argnums=(0,),
        in_shardings=(cache_sh, rep, rep),
        out_shardings=cache_sh,
    )
    # spill/restore/state-gather: the preemption path.  The spill gather
    # and the slot-state read produce REPLICATED payloads (they leave the
    # device for a host-side store); restore donates the cache like every
    # other admission-time mutation.
    page_spill = jax.jit(
        make_page_spill(cfg),
        in_shardings=(cache_sh, rep),
        out_shardings=rep,
    )
    page_restore = jax.jit(
        make_page_restore(cfg),
        donate_argnums=(0,),
        in_shardings=(cache_sh, rep, rep),
        out_shardings=cache_sh,
    )
    state_gather = jax.jit(
        make_slot_state_gather(cfg),
        in_shardings=(cache_sh, rep),
        out_shardings=rep,
    )
    out = {
        "serve_step": serve_step,
        "suffix_prefill": suffix_prefill,
        "state_insert": state_insert,
        "page_copy": page_copy,
        "page_spill": page_spill,
        "page_restore": page_restore,
        "state_gather": state_gather,
        "shardings": {
            "params": params_sh,
            "cache": cache_sh,
            "table": mat_sh,
            "slot_vec": vec_sh,
            "slot_keys": mat_sh,
            "replicated": rep,
        },
    }
    if speculate_k:
        sds = paged_decode_cache_specs(cfg, batch, n_pages, block_size)
        # stacked per-step verifier states: cache-leaf sharding with a
        # replicated leading step axis
        stacked_sh = {
            n: NamedSharding(
                mesh, PartitionSpec(None, *tuple(cache_sh[n].spec))
            )
            for n in sds
            if n not in PAGE_POOL_LEAVES and n != "quant_step"
        }
        out["spec_round"] = jax.jit(
            make_paged_spec_round(cfg, speculate_k),
            donate_argnums=(1,),
            in_shardings=(params_sh, cache_sh, mat_sh, vec_sh, mat_sh, vec_sh),
            out_shardings=(cache_sh, mat_sh, mat_sh, mat_sh, mat_sh, stacked_sh),
        )
        out["spec_rollback"] = jax.jit(
            make_spec_rollback(cfg),
            donate_argnums=(0,),
            in_shardings=(cache_sh, stacked_sh, rep, rep),
            out_shardings=cache_sh,
        )
    return out


def sample_tokens(
    cfg: ModelConfig, logits, key=None, steps=None, n_redundant: int = 1
):
    """Next-token selection shared by prefill and decode steps.

    ``logits`` is (B, V).  With ``key=None`` (or ``wta_head`` off) this is the
    digital argmax baseline.  With ``cfg.wta_head``:

      * ``key.ndim == 1`` — legacy whole-batch key: one WTA trial tensor for
        the batch (the static engine's behavior).
      * ``key.ndim == 2`` — per-slot keys (B, 2): each request votes with its
        own comparator-noise stream, so a request's sampled tokens are a
        function of (its key, its step counter, its logits) only — invariant
        to which other requests share the batch, which continuous batching
        requires.  ``steps`` (B,) int32, when given, is folded into each
        slot's key so every decode step draws fresh noise.

    The comparator operating point (threshold, noise sigma) is consulted
    from the ACTIVE device backend at trace time
    (``wta_readout_params`` — identity on healthy backends, perturbed by
    fault backends), so substrate faults reach the serving sampler.

    ``n_redundant = R > 1`` is the fault-mitigation re-read: the full WTA
    trial bank races R times (read 0 on the EXACT plain-path key, extra
    reads on a fold of the slot key by the read index) and the published
    token is the majority vote over the R reads (ties break to the lowest
    token id).  ``R = 1`` is byte-identical to the pre-knob trace.
    """
    if not (cfg.wta_head and key is not None):
        return jnp.argmax(logits, axis=-1).astype(_i32)

    from repro.core import wta as W
    from repro.kernels import backend as BK

    vth0, sigma_z = BK.get_backend().wta_readout_params(
        cfg.analog.vth0, W.wta_sigma_z(cfg.analog.beta)
    )

    def counts_one(k, z):
        res = W.wta_trials(
            k,
            z.astype(jnp.float32),
            n_trials=cfg.analog.wta_trials,
            vth0=vth0,
            sigma_z=sigma_z,
        )
        return res.counts

    def sample_once(k):
        if k.ndim == 2:  # per-slot keys
            if steps is not None:
                k = jax.vmap(jax.random.fold_in)(k, steps)
            counts = jax.vmap(counts_one)(k, logits)
        else:
            counts = counts_one(k, logits)
        return jnp.argmax(counts, axis=-1).astype(_i32)

    reads = max(int(n_redundant), 1)
    if reads == 1:
        return sample_once(key)
    votes = [sample_once(key)]
    for r in range(1, reads):
        if key.ndim == 2:
            kr = jax.vmap(jax.random.fold_in, in_axes=(0, None))(key, r)
        else:
            kr = jax.random.fold_in(key, r)
        votes.append(sample_once(kr))
    tally = jax.nn.one_hot(
        jnp.stack(votes, axis=0), logits.shape[-1], dtype=_i32
    ).sum(axis=0)
    return jnp.argmax(tally, axis=-1).astype(_i32)


def analog_call_profile(
    entry: str, *, tokens: int = 1, batch: int = 1, k: int = 0,
    redundant: int = 0,
) -> dict:
    """Analog-event multiplicities for ONE invocation of a serving entry
    point built in this module — the contract the energy accounting rides
    on (see kernels/backend.py and docs/serving.md §"Energy accounting").

    Each factory's device computation forwards a fixed number of token
    positions through the crossbar fabric; the returned dict states that
    number per kind, plus how many token-sampling decisions the call makes
    and how many of the forwarded tokens WRITE their K/V rows (int8 pools
    stochastically round exactly those):

    * ``suffix_prefill`` — one chunked-prefill step over ``tokens`` suffix
      positions (also the dense layout's monolithic prefill with
      ``tokens`` = the padded bucket).  No sampling: first-token sampling
      is the separate ``sample0`` call.
    * ``sample0`` — one first-token sampling decision from stored/terminal
      logits (prefill completion, full prefix hit, dense admission).
    * ``serve_step`` — one plain batched decode step: ``batch`` ACTIVE
      slots each forward + sample + write one token.  Padded idle slots
      compute against the trash page but serve no request; the Sim
      backend accounts logical work, which is what makes totals invariant
      to batch composition.
    * ``spec_round`` — one fused speculative round: per active slot,
      ``k`` drafted tokens (forwarded, sampled, K/V written) PLUS ``k``
      verify positions re-decoded read-only from the pre-draft snapshot
      (forwarded, resampled, ``kv_write=False`` — no rounding events).
      Rejected drafts burn this energy without emitting tokens; the bench
      reports gross vs per-published-token cost honestly.
    * page/state movement entry points (``page_copy``, ``page_spill``,
      ``page_restore``, ``state_gather``, ``state_insert``,
      ``spec_rollback``) — pure memory traffic, no crossbar events.

    ``redundant`` counts EXTRA comparator re-reads beyond the first
    (fault-mitigation majority voting): a serve step at
    ``n_redundant_reads = R`` passes ``redundant = (R-1)·batch``, each
    priced as one more per-sample comparator sweep
    (``cost_model.per_redundant_read_counts``) without adding sample
    events — the published stream is unchanged, only energy grows.
    """
    zero = dict(
        prefill=0, decode=0, draft=0, samples=0, kv_tokens=0, redundant=0
    )
    if entry == "suffix_prefill":
        return dict(zero, prefill=tokens, kv_tokens=tokens)
    if entry == "sample0":
        return dict(zero, samples=1)
    if entry == "serve_step":
        return dict(
            zero, decode=batch, samples=batch, kv_tokens=batch,
            redundant=redundant,
        )
    if entry == "spec_round":
        return dict(
            zero,
            draft=k * batch,
            decode=k * batch,
            samples=2 * k * batch,
            kv_tokens=k * batch,
        )
    if entry in (
        "page_copy", "page_spill", "page_restore", "state_gather",
        "state_insert", "spec_rollback",
    ):
        return zero
    raise ValueError(f"unknown serving entry point {entry!r}")


def params_specs(cfg: ModelConfig) -> Any:
    fns = get_model_fns(cfg)
    return jax.eval_shape(lambda k: fns.init(k, cfg), jax.random.PRNGKey(0))


def train_state_specs(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0)
    )


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token(B,)) -> (cache, token).

    With cfg.wta_head the next token comes from the paper's WTA stochastic
    SoftMax circuit (vote counts over noisy comparator trials) instead of a
    digital argmax — the serving-side integration of the technique.  ``key``
    may be a single PRNG key (whole-batch trials) or a (B, 2) stack of
    per-slot keys with an optional ``steps`` (B,) counter; see
    :func:`sample_tokens`."""
    fns = get_model_fns(cfg)

    def serve_step(params, cache, token, key=None, steps=None):
        cache, logits = fns.decode_step(params, cache, token, cfg)
        return cache, sample_tokens(cfg, logits, key, steps)

    return serve_step


# Per-slot logit-sanity codes emitted by the paged serve step (the third
# output).  0 is healthy; nonzero codes map to typed eviction reasons.
SANE_OK = 0
SANE_NAN = 1
SANE_SATURATED = 2
SANE_ENTROPY_COLLAPSE = 3
SANITY_REASONS = {
    SANE_NAN: "nan",
    SANE_SATURATED: "saturated",
    SANE_ENTROPY_COLLAPSE: "entropy_collapse",
}


def make_paged_serve_step(
    cfg: ModelConfig,
    *,
    n_redundant: int = 1,
    sat_threshold: float = 1e6,
    entropy_floor: float = 0.0,
):
    """One decode step over a paged cache:
    (params, cache, table(B,W), token(B,)) -> (cache, token, sane).

    ``table`` is the host scheduler's block table, sliced to the current
    window of W blocks — the only width the step touches, which is where
    the O(max_len) → O(valid blocks) decode saving comes from.  Each
    distinct W is one retrace of the same jit (the engine buckets W to a
    power of two, so compiles stay logarithmic in max_len).  ``key`` /
    ``steps`` follow the :func:`sample_tokens` contract, including the
    ``n_redundant`` majority-vote re-read knob.

    ``sane`` is a (B,) int32 logit-sanity code per slot (the detection
    half of the degraded-device loop, generalizing the old bool
    finite-logits flag):

    * ``SANE_NAN`` — a non-finite logit row (the original NaN/Inf guard);
    * ``SANE_SATURATED`` — finite but ``max|logit| > sat_threshold``: the
      analog range blew up (drift/stuck-at pushing pre-activations to the
      rail) without tripping the float limits yet;
    * ``SANE_ENTROPY_COLLAPSE`` — softmax entropy strictly below
      ``entropy_floor`` (only computed when the floor is positive, so the
      default trace is unchanged): the distribution pinned to one token,
      the classic stuck-column signature.

    The engine evicts a flagged slot with the matching typed reason
    instead of publishing a garbage token.  All checks ride on the logits
    the step already materializes — no extra device round trip."""
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def serve_step(params, cache, table, token, key=None, steps=None):
        cache, logits = TF.lm_decode_step(params, cache, token, cfg, table)
        zf = logits.astype(jnp.float32)
        finite = jnp.isfinite(zf).all(axis=-1)
        sat = jnp.max(jnp.abs(zf), axis=-1) > jnp.float32(sat_threshold)
        sane = jnp.where(
            finite,
            jnp.where(sat, SANE_SATURATED, SANE_OK),
            SANE_NAN,
        ).astype(_i32)
        if entropy_floor > 0.0:  # static: off => identical trace
            p = jax.nn.softmax(zf, axis=-1)
            ent = -jnp.sum(p * jnp.log(jnp.clip(p, 1e-30, 1.0)), axis=-1)
            collapsed = finite & ~sat & (ent < jnp.float32(entropy_floor))
            sane = jnp.where(collapsed, SANE_ENTROPY_COLLAPSE, sane).astype(
                _i32
            )
        tok = sample_tokens(cfg, logits, key, steps, n_redundant=n_redundant)
        return cache, tok, sane

    return serve_step


def make_page_spill(cfg: ModelConfig):
    """Gather a request's pool pages into a host-transferable payload.

    (paged_cache, ids (W,) int32) → {pool leaf: (nu, n_attn, W, bs, ...)}.
    The device half of preemption: the engine collects the victim's mapped
    pages (padded with the trash page to a FIXED width W, so one compile
    serves every spill), pulls the gathered payload to host memory, and
    frees the pages — the block pool sees the capacity back immediately.
    Reads only; the cache is NOT donated (it stays live for the surviving
    slots).  int8 pools spill code pages and scale planes together, so a
    restore is bit-exact at any pool dtype.
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def spill(cache: dict, ids) -> dict:
        return {
            name: cache[name][:, :, ids]
            for name in PAGE_POOL_LEAVES
            if name in cache
        }

    return spill


def make_page_restore(cfg: ModelConfig):
    """Scatter a spilled payload back onto freshly reserved pool pages.

    (paged_cache, ids (W,) int32, payload) → paged_cache.  Inverse of
    :func:`make_page_spill`: position ``i`` of ``ids`` receives row ``i``
    of every payload leaf.  Slots the engine does not want written (prefix
    pages that came back as index hits, padding) point at the trash page —
    duplicate trash ids are fine, nothing ever reads that page.  The cache
    IS donated: restore happens at admission, when the engine owns the
    only reference.
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def restore(cache: dict, ids, payload: dict) -> dict:
        out = dict(cache)
        for name, rows in payload.items():
            leaf = cache[name]
            out[name] = leaf.at[:, :, ids].set(rows.astype(leaf.dtype))
        return out

    return restore


def make_slot_state_gather(cfg: ModelConfig):
    """Read one slot's dense per-slot leaves out of a live paged cache.

    (paged_cache, slot int32) → state_leaves{B=1}.  Inverse of
    :func:`make_paged_state_insert` and shaped exactly like its input, so
    a spill→restore round trip is gather → (later) insert with no
    reshaping in between.  Covers ``pos`` plus the recurrent/SSM state
    leaves — everything a preempted request needs beyond its KV pages.
    The slot index is traced; one compile for the engine's lifetime.
    """
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def gather(cache: dict, slot) -> dict:
        # leaves WITHOUT a slot axis (the int8 pool's global quant_step
        # counter) are engine-wide, not per-request — a spill must not
        # capture them and a restore must not rewind them (replaying the
        # counter would replay stochastic-rounding draws)
        return {
            name: jax.lax.dynamic_slice_in_dim(
                leaf, slot, 1, axis=cache_batch_axis(cfg, name)
            )
            for name, leaf in cache.items()
            if name not in PAGE_POOL_LEAVES
            and leaf.ndim > cache_batch_axis(cfg, name)
        }

    return gather


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec):
    fns = get_model_fns(cfg)
    max_len = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)

    def prefill_step(params, batch):
        return fns.prefill(params, batch, cfg, max_len)

    return prefill_step


def input_specs(arch: str, shape_name: str, tcfg: TrainConfig | None = None):
    """The dry-run entry: (step_fn_kind, arg specs) for an (arch, shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        return {
            "kind": "train",
            "cfg": cfg,
            "shape": shape,
            "state": train_state_specs(cfg, tcfg),
            "batch": train_batch_specs(cfg, shape),
            "tcfg": tcfg,
        }
    if shape.kind == "prefill":
        return {
            "kind": "prefill",
            "cfg": cfg,
            "shape": shape,
            "params": params_specs(cfg),
            "batch": prefill_batch_specs(cfg, shape),
        }
    return {
        "kind": "decode",
        "cfg": cfg,
        "shape": shape,
        "params": params_specs(cfg),
        "cache": decode_cache_specs(cfg, shape),
        "token": _sds((shape.global_batch,), _i32),
    }
