"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

`input_specs(arch, shape)` returns weak-type-correct, shardable specs with
no device allocation, for the step function the shape's kind lowers:
  train   → train_step(state, batch)
  prefill → prefill_step(params, batch)
  decode  → serve_step(params, cache, token)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.models import ModelConfig, get_model_fns
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.train import TrainConfig, TrainState, init_train_state

WHISPER_DEC_PROMPT = 448  # decoder prompt length for encdec prefill cells

_i32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), _i32), "labels": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        out["frames"] = _sds((b, s, cfg.d_model), cfg.dtype)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # prefill_32k for whisper = encode S frames + short decoder prompt
        return {
            "frames": _sds((b, s, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, WHISPER_DEC_PROMPT), _i32),
        }
    out = {"tokens": _sds((b, s), _i32)}
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.d_model), cfg.dtype)
    return out


def decode_cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: ED.init_encdec_cache(cfg, b, s, cfg.enc_seq)
        )
    return jax.eval_shape(lambda: TF.init_decode_cache(cfg, b, s))


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Concrete (allocated) decode cache for a live serving batch."""
    if cfg.family == "encdec":
        return ED.init_encdec_cache(cfg, batch, max_len, cfg.enc_seq)
    return TF.init_decode_cache(cfg, batch, max_len)


def init_paged_decode_cache(
    cfg: ModelConfig, batch: int, n_pages: int, block_size: int
) -> dict:
    """Concrete paged decode cache (shared block pool + per-slot states)."""
    if cfg.family == "encdec":
        raise ValueError("paged KV cache is token-LM only (no encdec)")
    return TF.init_paged_decode_cache(cfg, batch, n_pages, block_size)


def paged_decode_cache_specs(
    cfg: ModelConfig, batch: int, n_pages: int, block_size: int
) -> dict:
    return jax.eval_shape(
        lambda: init_paged_decode_cache(cfg, batch, n_pages, block_size)
    )


def cache_batch_axis(cfg: ModelConfig, leaf_name: str) -> int:
    """Which axis of a decode-cache leaf is the request/slot axis.

    ``pos`` is (B,); LM-family leaves are (n_units, n_per_unit, B, ...);
    encdec leaves are (n_layers, B, ...) — the layout contract that
    slot-addressable insertion below relies on.  Covered by
    tests/test_specs.py so cache-layout refactors fail loudly.
    """
    if leaf_name == "pos":
        return 0
    return 1 if cfg.family == "encdec" else 2


def make_cache_insert(cfg: ModelConfig):
    """Insert one request's prefill cache into a live batch cache at ``slot``.

    (batch_cache, one_cache(B=1), slot int32) -> batch_cache.  The slot index
    is a traced scalar, so one jit of this function serves every slot of a
    live batch without recompiling — the continuous-batching refill path.
    """

    def insert(batch_cache: dict, one_cache: dict, slot) -> dict:
        out = {}
        for name, leaf in batch_cache.items():
            upd = one_cache[name].astype(leaf.dtype)
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                leaf, upd, slot, axis=cache_batch_axis(cfg, name)
            )
        return out

    return insert


def make_paged_cache_insert(cfg: ModelConfig):
    """Insert one request's prefill cache into the paged batch cache.

    (paged_cache, one_cache(B=1, len=L·), slot int32, table_row int32
    [, quant_seeds]) → paged_cache.  The one-request cache comes out of the
    ordinary dense prefill, built at a window already padded to a block
    multiple; its K/V are reshaped into blocks and scattered to the pages
    named by the first ``L/block_size`` entries of ``table_row``.  Dense
    per-slot leaves (pos, recurrent/SSM states) use the slot-addressable
    update.  Slot and page ids are traced, so one compile per prefill
    bucket serves every (slot, page set) of a live batch.

    Int8 pools (``k_scale_pages`` present): the dense prefill K/V stay full
    precision and are quantized HERE, one block at a time — per-(position,
    head) scale, codes stochastically rounded
    (kernels.ops.quantize_kv_pair_int8) under the per-block ``quant_seeds``
    ((L/block_size,) uint32).  The engine derives each block's seed from
    its *content chain hash* (scheduler.prefix_block_hashes), NOT from the
    request id: any re-prefill of the same prompt prefix then produces
    bit-identical codes, which is what lets prefix sharing map an int8
    block into several requests' tables (a request-keyed seed would make
    the "same" block byte-diverge per request).  The seed vector is
    traced: one compile per prefill bucket, same as the rest.
    """
    from repro.kernels import ops as KOPS

    def insert(
        batch_cache: dict, one_cache: dict, slot, table_row, quant_seeds=None
    ) -> dict:
        out = {}
        int8_pool = "k_scale_pages" in batch_cache
        if int8_pool:
            # blockwise quantization under content-derived per-block seeds;
            # element counters restart per block, so (block content, seed)
            # fully determines the codes regardless of block position in
            # the prefill window
            src_k, src_v = one_cache["k"], one_cache["v"]
            nu, na, _, lpad, hkv, dh = src_k.shape
            bs = batch_cache["k_pages"].shape[3]
            assert lpad % bs == 0, (
                f"prefill window {lpad} not a multiple of the KV block "
                f"size {bs}"
            )
            nb = lpad // bs
            kb = src_k[:, :, 0].reshape(nu, na, nb, bs, hkv, dh)
            vb = src_v[:, :, 0].reshape(nu, na, nb, bs, hkv, dh)
            kc, ks, vc, vs = [], [], [], []
            for b in range(nb):
                k8, ksc, v8, vsc = KOPS.quantize_kv_pair_int8(
                    kb[:, :, b], vb[:, :, b], quant_seeds[b]
                )
                kc.append(k8)
                ks.append(ksc)
                vc.append(v8)
                vs.append(vsc)
            quantized = {
                "k_pages": (jnp.stack(kc, axis=2), jnp.stack(ks, axis=2)),
                "v_pages": (jnp.stack(vc, axis=2), jnp.stack(vs, axis=2)),
            }
        for name, leaf in batch_cache.items():
            if name in ("k_pages", "v_pages"):
                src = one_cache[name[0]]  # dense "k"/"v": (nu,na,1,L,Hkv,Dh)
                nu, na, _, lpad, hkv, dh = src.shape
                bs = leaf.shape[3]
                assert lpad % bs == 0, (
                    f"prefill window {lpad} not a multiple of the KV block "
                    f"size {bs}"
                )
                nb = lpad // bs
                if int8_pool:
                    blocks, sblocks = quantized[name]
                    out[name] = leaf.at[:, :, table_row[:nb]].set(blocks)
                    sleaf = batch_cache[f"{name[0]}_scale_pages"]
                    out[f"{name[0]}_scale_pages"] = sleaf.at[
                        :, :, table_row[:nb]
                    ].set(sblocks)
                else:
                    blocks = src[:, :, 0].reshape(nu, na, nb, bs, hkv, dh)
                    out[name] = leaf.at[:, :, table_row[:nb]].set(
                        blocks.astype(leaf.dtype)
                    )
            elif name in ("k_scale_pages", "v_scale_pages"):
                continue  # written alongside k_pages/v_pages above
            elif name == "quant_step":
                out[name] = leaf  # decode-step counter: inserts don't tick it
            else:
                upd = one_cache[name].astype(leaf.dtype)
                out[name] = jax.lax.dynamic_update_slice_in_dim(
                    leaf, upd, slot, axis=cache_batch_axis(cfg, name)
                )
        return out

    return insert


# page-pool cache leaves (vs the dense per-slot leaves) — the split that
# prefix sharing relies on: pool leaves are mapped through block tables and
# may be shared across slots, per-slot leaves are always private
PAGE_POOL_LEAVES = (
    "k_pages", "v_pages", "k_scale_pages", "v_scale_pages"
)


def make_paged_state_insert(cfg: ModelConfig):
    """Insert only the dense per-slot leaves of a one-request cache.

    (paged_cache, state_leaves{B=1}, slot int32) → paged_cache.  The
    prefix-sharing full-hit admission path: when every block covering a
    request's padded prompt is already resident (matched through the
    allocator's content-hash index), the engine maps the shared pages into
    the slot's table row and skips the prefill — but the per-slot leaves
    (``pos``, recurrent/SSM states) still need the stored values from the
    original prefill.  ``state_leaves`` holds exactly those leaves (no
    ``k``/``v``); their shapes are bucket-independent, so this compiles
    ONCE for the engine's whole lifetime.
    """

    def insert(batch_cache: dict, state_leaves: dict, slot) -> dict:
        out = dict(batch_cache)
        for name, upd in state_leaves.items():
            leaf = batch_cache[name]
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                leaf, upd.astype(leaf.dtype), slot,
                axis=cache_batch_axis(cfg, name),
            )
        return out

    return insert


def make_page_copy(cfg: ModelConfig):
    """Copy one pool page onto another across every page-pool leaf.

    (paged_cache, src int32, dst int32) → paged_cache.  The device half of
    a copy-on-write fork: the engine repoints the writer's table row at
    ``dst`` and the batched decode step then writes there, while the other
    owners keep reading the pristine ``src``.  int8 pools copy the scale
    planes alongside the code pages.  Page ids are traced — one compile
    serves every fork.
    """

    def copy(cache: dict, src, dst) -> dict:
        out = dict(cache)
        for name in PAGE_POOL_LEAVES:
            if name in cache:
                leaf = cache[name]  # (nu, n_attn, P, bs, ...)
                out[name] = leaf.at[:, :, dst].set(leaf[:, :, src])
        return out

    return copy


def sample_tokens(cfg: ModelConfig, logits, key=None, steps=None):
    """Next-token selection shared by prefill and decode steps.

    ``logits`` is (B, V).  With ``key=None`` (or ``wta_head`` off) this is the
    digital argmax baseline.  With ``cfg.wta_head``:

      * ``key.ndim == 1`` — legacy whole-batch key: one WTA trial tensor for
        the batch (the static engine's behavior).
      * ``key.ndim == 2`` — per-slot keys (B, 2): each request votes with its
        own comparator-noise stream, so a request's sampled tokens are a
        function of (its key, its step counter, its logits) only — invariant
        to which other requests share the batch, which continuous batching
        requires.  ``steps`` (B,) int32, when given, is folded into each
        slot's key so every decode step draws fresh noise.
    """
    if not (cfg.wta_head and key is not None):
        return jnp.argmax(logits, axis=-1).astype(_i32)

    from repro.core import wta as W

    def counts_one(k, z):
        res = W.wta_trials(
            k,
            z.astype(jnp.float32),
            n_trials=cfg.analog.wta_trials,
            vth0=cfg.analog.vth0,
            beta=cfg.analog.beta,
        )
        return res.counts

    if key.ndim == 2:  # per-slot keys
        if steps is not None:
            key = jax.vmap(jax.random.fold_in)(key, steps)
        counts = jax.vmap(counts_one)(key, logits)
    else:
        counts = counts_one(key, logits)
    return jnp.argmax(counts, axis=-1).astype(_i32)


def params_specs(cfg: ModelConfig) -> Any:
    fns = get_model_fns(cfg)
    return jax.eval_shape(lambda k: fns.init(k, cfg), jax.random.PRNGKey(0))


def train_state_specs(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0)
    )


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token(B,)) -> (cache, token).

    With cfg.wta_head the next token comes from the paper's WTA stochastic
    SoftMax circuit (vote counts over noisy comparator trials) instead of a
    digital argmax — the serving-side integration of the technique.  ``key``
    may be a single PRNG key (whole-batch trials) or a (B, 2) stack of
    per-slot keys with an optional ``steps`` (B,) counter; see
    :func:`sample_tokens`."""
    fns = get_model_fns(cfg)

    def serve_step(params, cache, token, key=None, steps=None):
        cache, logits = fns.decode_step(params, cache, token, cfg)
        return cache, sample_tokens(cfg, logits, key, steps)

    return serve_step


def make_paged_serve_step(cfg: ModelConfig):
    """One decode step over a paged cache:
    (params, cache, table(B,W), token(B,)) -> (cache, token).

    ``table`` is the host scheduler's block table, sliced to the current
    window of W blocks — the only width the step touches, which is where
    the O(max_len) → O(valid blocks) decode saving comes from.  Each
    distinct W is one retrace of the same jit (the engine buckets W to a
    power of two, so compiles stay logarithmic in max_len).  ``key`` /
    ``steps`` follow the :func:`sample_tokens` contract."""
    if cfg.family == "encdec":
        raise ValueError("paged serving is token-LM only (no encdec)")

    def serve_step(params, cache, table, token, key=None, steps=None):
        cache, logits = TF.lm_decode_step(params, cache, token, cfg, table)
        return cache, sample_tokens(cfg, logits, key, steps)

    return serve_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec):
    fns = get_model_fns(cfg)
    max_len = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)

    def prefill_step(params, batch):
        return fns.prefill(params, batch, cfg, max_len)

    return prefill_step


def input_specs(arch: str, shape_name: str, tcfg: TrainConfig | None = None):
    """The dry-run entry: (step_fn_kind, arg specs) for an (arch, shape)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        return {
            "kind": "train",
            "cfg": cfg,
            "shape": shape,
            "state": train_state_specs(cfg, tcfg),
            "batch": train_batch_specs(cfg, shape),
            "tcfg": tcfg,
        }
    if shape.kind == "prefill":
        return {
            "kind": "prefill",
            "cfg": cfg,
            "shape": shape,
            "params": params_specs(cfg),
            "batch": prefill_batch_specs(cfg, shape),
        }
    return {
        "kind": "decode",
        "cfg": cfg,
        "shape": shape,
        "params": params_specs(cfg),
        "cache": decode_cache_specs(cfg, shape),
        "token": _sds((shape.global_batch,), _i32),
    }
