"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step, *, warmup: int = 100, total: int = 10_000, floor: float = 0.1
):
    """Linear warmup then cosine decay to ``floor`` of peak; returns a scale
    in [0, 1] multiplying AdamWConfig.lr."""
    s = jnp.asarray(step, jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
