"""AdamW with optional low-precision moment states.

At 340B-parameter scale, f32 Adam moments (8 bytes/param) dominate HBM; we
store m and v in bf16 with *stochastic rounding* so the quantization is
unbiased and training statistics are preserved — the rounding primitive is
the same conductance-programming operator as the paper's weight writes
(kernels/stoch_round; jnp path used off-TPU).

All state tensors inherit the parameter's sharding (FSDP-compatible).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "bfloat16"   # moment storage dtype
    stochastic_rounding: bool = True


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def _sround(x: jax.Array, dt, key: Optional[jax.Array]) -> jax.Array:
    """Unbiased stochastic rounding f32 -> dt (bf16): perturb the mantissa
    below the target precision with uniform noise, then truncate."""
    if dt == jnp.float32 or key is None:
        return x.astype(dt)
    # bf16 keeps the top 16 bits of the f32 pattern; add uniform dither in
    # the truncated 16 bits => unbiased round-to-nearest-or-down.
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.randint(
        key, x.shape, 0, 1 << 16, dtype=jnp.uint32
    )
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(dt)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: AdamWState,
    lr_scale: jax.Array | float = 1.0,
    rng: Optional[jax.Array] = None,
) -> tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    dt = jnp.dtype(cfg.state_dtype)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    use_sr = cfg.stochastic_rounding and rng is not None

    new_p, new_m, new_v = [], [], []
    for i, (p, g, m, v) in enumerate(zip(flat_p, flat_g, flat_m, flat_v)):
        gf = g.astype(jnp.float32) * clip
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mhat = mf / bc1
        vhat = vf / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            upd = upd + cfg.weight_decay * pf
        pf = pf - lr * upd
        if use_sr:
            ki = jax.random.fold_in(rng, i)
            k1, k2 = jax.random.split(ki)
            new_m.append(_sround(mf, dt, k1))
            new_v.append(_sround(vf, dt, k2))
        else:
            new_m.append(mf.astype(dt))
            new_v.append(vf.astype(dt))
        new_p.append(pf.astype(p.dtype))

    params2 = jax.tree.unflatten(treedef, new_p)
    m2 = jax.tree.unflatten(treedef, new_m)
    v2 = jax.tree.unflatten(treedef, new_v)
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
    return params2, AdamWState(step=step, m=m2, v=v2), metrics
