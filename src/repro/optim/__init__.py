"""Optimizers and distributed-optimization tricks.

adamw      — AdamW with optional low-precision moments rounded stochastically
             (the paper's conductance-programming primitive reused as an
             optimizer trick: unbiased bf16 states, §kernels/stoch_round).
compress   — int8 gradient compression with error feedback for the
             cross-replica reduction path.
schedule   — warmup-cosine LR.
"""

from .adamw import AdamWConfig, adamw_init, adamw_update
from .compress import CompressState, compress_grads, init_compress
from .schedule import warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "CompressState",
    "compress_grads",
    "init_compress",
    "warmup_cosine",
]
