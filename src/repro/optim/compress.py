"""int8 gradient compression with error feedback.

Before the cross-replica reduction of microbatch gradients, each leaf is
quantized to int8 (per-leaf absmax scale) with *stochastic rounding*
(unbiased — the paper's programming primitive again) plus an error-feedback
accumulator that carries the quantization residual into the next step, so
the compressed SGD trajectory provably tracks the uncompressed one.

In the grad-accumulation loop this models a compressed all-reduce: each
microbatch contribution is compressed before summation (8× reduction of
reduction traffic); flag-gated via TrainConfig.compress_grads.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    error: Any  # error-feedback residual per leaf (param dtype)


def init_compress(params: Any) -> CompressState:
    return CompressState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _quantize_leaf(g: jax.Array, key: jax.Array) -> jax.Array:
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-30
    t = gf / scale
    floor = jnp.floor(t)
    frac = t - floor
    up = jax.random.uniform(key, t.shape) < frac
    q = jnp.clip(floor + up.astype(jnp.float32), -127, 127)
    return q * scale  # dequantized int8 grid value


def compress_grads(
    grads: Any,
    state: CompressState,
    key: Optional[jax.Array],
) -> tuple[Any, CompressState]:
    """Returns (compressed grads, new error state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out_g, out_e = [], []
    for i, (g, e) in enumerate(zip(flat_g, flat_e)):
        corrected = g.astype(jnp.float32) + e
        if key is None:
            q = corrected
        else:
            q = _quantize_leaf(corrected, jax.random.fold_in(key, i))
        out_g.append(q.astype(g.dtype))
        out_e.append(corrected - q)
    return (
        jax.tree.unflatten(treedef, out_g),
        CompressState(error=jax.tree.unflatten(treedef, out_e)),
    )
