"""Pallas TPU kernels for the RACA hot spots.

crossbar_mac    — fused quantize→MAC→thermal-noise→comparator (paper core)
wta_kernel      — multi-trial WTA vote counting (SoftMax neuron readout)
stoch_round     — stochastic-rounding quantizer (conductance programming;
                  reused for optimizer-state rounding and grad compression)
paged_attention — serving decode: block-table gather + online-softmax over
                  a paged KV cache (scalar-prefetched table drives the DMA)

Validated bit-exactly against the pure-jnp oracles in ref.py (shared
counter-based PRNG, see prng.py).  ops.py holds the public jit'd wrappers,
which dispatch through the pluggable device backend in backend.py (Sim by
default — today's Pallas/jnp math plus analog-event accounting; the seam
for hardware-in-the-loop Phys backends later).  EXAMPLE.md documents the
layout convention.
"""
