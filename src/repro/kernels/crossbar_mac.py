"""Fused RACA crossbar kernel: quantize → MAC → thermal noise → comparator.

This is the paper's compute hot spot as a single TPU kernel.  One pass over
the weights performs, entirely in VMEM:

  1. conductance-grid quantization of the weight tile (Eq. 4-7),
  2. the MXU matmul accumulation (the crossbar dot product, Eq. 9/12),
  3. per-column ΣG accumulation (the physical noise variance, Eq. 11/13),
  4. Gaussian thermal-noise synthesis (counter-based PRNG, see prng.py),
  5. the comparator: stochastic binarization (Eq. 8) or linear readout.

TPU adaptation of the paper's circuit: crossbar tiles map to MXU-aligned
(128-multiple) VMEM blocks; the analog current summing across row tiles
becomes the sequential K-grid accumulation in a f32 VMEM scratch; the
comparator bank is the VPU compare at the final K step.  HBM traffic is one
read of x and W and one write of the (binary) output — the fusion is the
kernel-level payoff of removing the "ADC" (no intermediate z round-trip).

Grid: (M/bm, N/bn, K/bk), K innermost (sequential accumulation).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

from . import prng

DEF_BM, DEF_BN, DEF_BK = 128, 128, 512


def _kernel(
    x_ref,      # (bm, bk) f32
    w_ref,      # (bk, bn) f32
    seed_ref,   # (2,) int32, SMEM: [seed, bitcast-f32 sigma_z]
    o_ref,      # (bm, bn) f32
    acc_ref,    # (bm, bn) f32 VMEM scratch: z accumulator
    wsum_ref,   # (1, bn)  f32 VMEM scratch: per-column Σ W_q
    *,
    nk: int,
    n_padded: int,
    valid_k: int,
    binarize: bool,
    physical_noise: bool,
    noise_params: tuple,  # (four_ktdf, g0, g_ref, v_read, k_rows)
    quantize: bool,
    qstep: float,
    w_min: float,
    w_max: float,
):
    # grid indices read at the top level: program_id inside a pl.when branch
    # is not substituted by interpret mode on older jax (cpu tests)
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        wsum_ref[...] = jnp.zeros_like(wsum_ref)

    w = w_ref[...]
    if quantize:
        # Round-to-nearest onto the conductance grid (in-VMEM, never
        # materialized in HBM).  Reciprocal-multiply keeps the level decision
        # bit-identical across backends (see stoch_round.py).
        w = jnp.clip(w, w_min, w_max)
        w = jnp.round((w - w_min) * jnp.float32(1.0 / qstep)) * qstep + w_min
    bk = w.shape[0]
    if valid_k % bk != 0:
        # Zero out K-padding rows: physical rows beyond the matrix must not
        # contribute to either the MAC or the ΣG noise variance.
        krow = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0) + k * bk
        w = jnp.where(krow < valid_k, w, 0.0)

    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )
    if physical_noise:
        wsum_ref[...] += jnp.sum(w, axis=0, keepdims=True)

    @pl.when(k == nk - 1)
    def _readout():
        z = acc_ref[...]
        if physical_noise:
            four_ktdf, g0, g_ref, v_read, k_rows = noise_params
            sum_g = g0 * wsum_ref[...] + 2.0 * k_rows * g_ref
            sigma = jnp.sqrt(four_ktdf * sum_g) / (v_read * g0)
        else:
            # runtime sigma (depends on the traced dynamic-range scale)
            sigma = jax.lax.bitcast_convert_type(seed_ref[1], jnp.float32)
        # Globally-unique per-element counter -> reproducible thermal noise.
        bm, bn = z.shape
        rows = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1)
        gidx = (rows + jnp.uint32(i * bm)) * jnp.uint32(n_padded) + (
            cols + jnp.uint32(j * bn)
        )
        noise = prng.gaussian(gidx, seed_ref[0].astype(jnp.uint32)) * sigma
        v = z + noise
        if binarize:
            o_ref[...] = (v > 0.0).astype(jnp.float32)
        else:
            o_ref[...] = v


def crossbar_mac_pallas(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array,  # (2,) int32: [seed, bitcast-f32 sigma_z]
    *,
    binarize: bool = True,
    physical_noise: bool = False,
    noise_params: tuple = (0.0, 1.0, 0.0, 1.0, 0),
    quantize: bool = True,
    qstep: float = 2.0 / 31,
    w_min: float = -1.0,
    w_max: float = 1.0,
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    bk: int = DEF_BK,
    valid_k: int | None = None,
    interpret: bool | object = False,
):
    """Raw pallas_call wrapper.  x: (M, K) f32, w: (K, N) f32 — M, K, N must
    already be multiples of (bm, bk, bn); use ops.crossbar_mac for padding,
    STE gradients and key handling."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, (
        (m, k, n, bm, bn, bk)
    )
    nk = k // bk
    kern = functools.partial(
        _kernel,
        nk=nk,
        n_padded=n,
        valid_k=k if valid_k is None else valid_k,
        binarize=binarize,
        physical_noise=physical_noise,
        noise_params=noise_params,
        quantize=quantize,
        qstep=qstep,
        w_min=w_min,
        w_max=w_max,
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x.astype(jnp.float32), w.astype(jnp.float32), seed)
