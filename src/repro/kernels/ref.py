"""Pure-jnp oracles for every Pallas kernel.

Each function reproduces the corresponding kernel *bit-exactly* (same
counter-based PRNG, same quantization, same accumulation order up to f32
matmul reassociation) so tests can assert_allclose with tight tolerances
even on the stochastic paths.  These are also the implementations used
inside the 512-device dry-run compile (core/analog.py falls back here off
TPU), so kernel and reference must stay semantically identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import prng


def _quantize(w, qstep, w_min, w_max):
    w = jnp.clip(w, w_min, w_max)
    return jnp.round((w - w_min) * jnp.float32(1.0 / qstep)) * qstep + w_min


def crossbar_mac_ref(
    x: jax.Array,
    w: jax.Array,
    seed: jax.Array,
    *,
    binarize: bool = True,
    physical_noise: bool = False,
    sigma_z: jax.Array | float = 1.702,
    noise_params: tuple = (0.0, 1.0, 0.0, 1.0, 0),
    quantize: bool = True,
    qstep: float = 2.0 / 31,
    w_min: float = -1.0,
    w_max: float = 1.0,
    valid_k: int | None = None,
) -> jax.Array:
    """Oracle for crossbar_mac_pallas on already-padded (M,K)x(K,N) inputs."""
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if quantize:
        wf = _quantize(wf, qstep, w_min, w_max)
    if valid_k is not None and valid_k != wf.shape[0]:
        krow = jax.lax.broadcasted_iota(jnp.int32, wf.shape, 0)
        wf = jnp.where(krow < valid_k, wf, 0.0)
    z = xf @ wf
    if physical_noise:
        four_ktdf, g0, g_ref, v_read, k_rows = noise_params
        sum_g = g0 * wf.sum(axis=0, keepdims=True) + 2.0 * k_rows * g_ref
        sigma = jnp.sqrt(four_ktdf * sum_g) / (v_read * g0)
    else:
        sigma = jnp.float32(sigma_z)
    m, n = z.shape
    rows = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 1)
    gidx = rows * jnp.uint32(n) + cols
    noise = prng.gaussian(gidx, jnp.asarray(seed).astype(jnp.uint32)) * sigma
    v = z + noise
    return (v > 0.0).astype(jnp.float32) if binarize else v


def wta_counts_ref(
    z: jax.Array,
    seed: jax.Array,
    *,
    n_trials: int,
    vth0: float,
    sigma_z: float,
    valid_c: int | None = None,
    bm: int = 128,
) -> jax.Array:
    """Oracle for wta_counts_pallas.  Reproduces the kernel's counter layout
    (per-block row indices, trial stride) exactly."""
    b, c = z.shape
    if valid_c is None:
        valid_c = c
    zf = z.astype(jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (b, c), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (b, c), 1)
    base_idx = rows * jnp.uint32(c) + cols
    pad_mask = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1) < valid_c
    seed_u = jnp.asarray(seed).astype(jnp.uint32)
    neg_inf = jnp.float32(jnp.finfo(jnp.float32).min)

    def trial(t, counts):
        idx = base_idx + jnp.uint32(t) * jnp.uint32(bm * c) * jnp.uint32(4096)
        v = zf + prng.gaussian(idx, seed_u) * jnp.float32(sigma_z)
        fired = (v > jnp.float32(vth0)) & pad_mask
        any_fired = jnp.any(fired, axis=-1, keepdims=True)
        v_masked = jnp.where(fired, v, neg_inf)
        vmax = jnp.max(v_masked, axis=-1, keepdims=True)
        winner = (v_masked == vmax) & any_fired
        return counts + winner.astype(jnp.float32)

    return jax.lax.fori_loop(
        0, n_trials, trial, jnp.zeros((b, c), jnp.float32)
    )


def paged_attention_ref(
    q: jax.Array,        # (B, H, Dh)
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (B, W) int32 page ids; <0 treated as page 0
    pos: jax.Array,      # (B,) int32 last valid key position
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Oracle for paged_attention_pallas: gather the table's blocks into a
    contiguous (W·bs) window, then masked full-softmax attention.  Block i
    holds logical positions [i·bs, (i+1)·bs); positions beyond ``pos`` (and
    outside the local window) get NEG_INF scores — exactly zero probability
    in f32.

    With int8 pools the per-(page, slot-in-page, head) scale planes are
    gathered through the same table and folded into scores / softmax
    weights (never into the cache): scores pick up ``k_scale/127`` and the
    value reduction weights pick up ``v_scale/127`` — the same ordering as
    the dense int8 trick in models.attention.attend_one_token."""
    neg_inf = jnp.float32(-2.0e38)
    b, h, dh = q.shape
    _, bs, hkv, _ = k_pages.shape
    g = h // hkv
    pages = jnp.maximum(table, 0)
    kb = k_pages[pages].reshape(b, -1, hkv, dh)
    vb = v_pages[pages].reshape(b, -1, hkv, dh)
    t = kb.shape[1]
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) * jnp.float32(
        dh**-0.5
    )
    sc = jnp.einsum(
        "bkgd,btkd->bkgt", qg, kb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if k_scale is not None:
        ks = k_scale[pages].reshape(b, t, hkv)
        sc = sc * (ks.transpose(0, 2, 1) / 127.0)[:, :, None, :]
    if softcap > 0.0:
        sc = jnp.tanh(sc / jnp.float32(softcap)) * jnp.float32(softcap)
    kpos = jnp.arange(t)[None]
    ok = kpos <= pos[:, None]
    if kind == "local":
        ok &= kpos > (pos[:, None] - local_window)
    sc = sc + jnp.where(ok, 0.0, neg_inf)[:, None, None, :]
    w = jax.nn.softmax(sc, axis=-1)
    if v_scale is not None:
        vs = v_scale[pages].reshape(b, t, hkv)
        w = w * (vs.transpose(0, 2, 1) / 127.0)[:, :, None, :]
    out = jnp.einsum(
        "bkgt,btkd->bkgd", w, vb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, h, dh)


def prefill_attention_ref(
    q: jax.Array,        # (S, H, Dh) — one request's suffix-chunk queries
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (W,) int32 page ids; <0 treated as page 0
    q0: jax.Array,       # () int32 absolute position of the first query
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Oracle for prefill_attention_pallas: gather the request's blocks
    into a contiguous (W·bs) window, then masked full-softmax attention
    for every suffix query at once.  Query i sits at absolute position
    ``q0 + i`` and key t of block w at ``w·bs + t``, so the causal / local
    mask is exact even though the query tile starts mid-prompt (the whole
    point: suffix queries attend into shared prefix pages).

    int8 pools fold the gathered scale planes into scores / softmax
    weights exactly like :func:`paged_attention_ref` (scores pick up
    ``k_scale/127``, value-reduction weights ``v_scale/127`` — the cache
    itself is never dequantized)."""
    neg_inf = jnp.float32(-2.0e38)
    s, h, dh = q.shape
    _, bs, hkv, _ = k_pages.shape
    g = h // hkv
    pages = jnp.maximum(table, 0)
    kb = k_pages[pages].reshape(-1, hkv, dh)
    vb = v_pages[pages].reshape(-1, hkv, dh)
    t = kb.shape[0]
    qg = q.reshape(s, hkv, g, dh).astype(jnp.float32) * jnp.float32(
        dh**-0.5
    )
    sc = jnp.einsum(
        "skgd,tkd->kgst", qg, kb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if k_scale is not None:
        ks = k_scale[pages].reshape(t, hkv)
        sc = sc * (ks.transpose(1, 0) / 127.0)[:, None, None, :]
    if softcap > 0.0:
        sc = jnp.tanh(sc / jnp.float32(softcap)) * jnp.float32(softcap)
    qpos = q0 + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if kind == "local":
        ok &= kpos > (qpos - local_window)
    sc = sc + jnp.where(ok, 0.0, neg_inf)[None, None, :, :]
    w = jax.nn.softmax(sc, axis=-1)
    if v_scale is not None:
        vs = v_scale[pages].reshape(t, hkv)
        w = w * (vs.transpose(1, 0) / 127.0)[:, None, None, :]
    out = jnp.einsum(
        "kgst,tkd->skgd", w, vb.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.reshape(s, h, dh)


def stoch_round_ref(
    x: jax.Array,
    seed: jax.Array,
    *,
    step: float,
    lo: float,
    hi: float,
) -> jax.Array:
    """Oracle for stoch_round_pallas on padded (M, N) input."""
    m, n = x.shape
    xf = jnp.clip(x.astype(jnp.float32), lo, hi)
    t = (xf - lo) * jnp.float32(1.0 / step)  # see stoch_round.py note
    floor = jnp.floor(t)
    frac = t - floor
    rows = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 1)
    idx = rows * jnp.uint32(n) + cols
    u = prng.uniform(idx, jnp.asarray(seed).astype(jnp.uint32))
    q = floor + (u < frac).astype(jnp.float32)
    return q * jnp.float32(step) + jnp.float32(lo)
