"""Pallas-TPU API compatibility across jax releases.

The kernels target the current Pallas API; older jax releases (< 0.5) spell
some symbols differently.  Centralizing the aliases here keeps every kernel
module importable (and testable in interpret mode) on whichever jax the
container ships.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in jax 0.5
CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def interpret_mode():
    """Value for ``pallas_call(interpret=...)`` on the current backend."""
    if jax.default_backend() == "tpu":
        return False
    # jax >= 0.6 structures TPU interpret-mode options in InterpretParams;
    # older releases only take pallas_call(interpret=True)
    if hasattr(pltpu, "InterpretParams"):
        return pltpu.InterpretParams()
    return True
