"""WTA binary stochastic SoftMax kernel (paper §III-B, Fig. 3/5).

Simulates T decision trials of the adaptive-threshold comparator bank for a
block of rows entirely in VMEM:

  per trial: V_j = z_j + n_j,  n_j ~ N(0, σ²)   (thermal noise)
             fired = V_j > V_th0                (comparator bank)
             winner = argmax over fired V_j     (threshold race)
             counts[winner] += 1 if any fired   (§III-C vote counter)

The trial loop is a fori_loop over on-chip state — z is read from HBM once
for all T trials instead of T times (the fusion win; a naive jnp
implementation materializes a (T, B, C) noise tensor in HBM).

Grid: (B/bm,); block (bm, C) with the class axis resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

from . import prng

DEF_BM = 128


def _kernel(
    z_ref,     # (bm, C) f32
    seed_ref,  # (1,) int32 SMEM
    cnt_ref,   # (bm, C) f32 out: winner counts
    *,
    n_trials: int,
    vth0: float,
    sigma_z: float,
    c_padded: int,
    valid_c: int,
):
    z = z_ref[...]
    bm, c = z.shape
    i = pl.program_id(0)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (bm, c), 0) + jnp.uint32(
        i * bm
    )
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bm, c), 1)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1)
    pad_mask = col_ids < valid_c  # padded classes can never fire
    base_idx = rows * jnp.uint32(c_padded) + cols
    seed = seed_ref[0].astype(jnp.uint32)
    neg_inf = jnp.float32(jnp.finfo(jnp.float32).min)

    def trial(t, counts):
        idx = base_idx + jnp.uint32(t) * jnp.uint32(bm * c_padded) * jnp.uint32(
            4096
        )
        v = z + prng.gaussian(idx, seed) * jnp.float32(sigma_z)
        fired = (v > jnp.float32(vth0)) & pad_mask
        any_fired = jnp.any(fired, axis=-1, keepdims=True)
        v_masked = jnp.where(fired, v, neg_inf)
        vmax = jnp.max(v_masked, axis=-1, keepdims=True)
        # argmax as "equals max" one-hot; exact ties get split votes — a
        # measure-zero event for continuous noise.
        winner = (v_masked == vmax) & any_fired
        return counts + winner.astype(jnp.float32)

    cnt_ref[...] = jax.lax.fori_loop(
        0, n_trials, trial, jnp.zeros((bm, c), jnp.float32)
    )


def wta_counts_pallas(
    z: jax.Array,
    seed: jax.Array,
    *,
    n_trials: int,
    vth0: float,
    sigma_z: float,
    valid_c: int | None = None,
    bm: int = DEF_BM,
    interpret: bool | object = False,
):
    """z: (B, C) f32, B multiple of bm, C a multiple of 128 (pad in ops.py).
    Returns winner counts (B, C) f32."""
    b, c = z.shape
    assert b % bm == 0, (b, bm)
    kern = functools.partial(
        _kernel,
        n_trials=n_trials,
        vth0=vth0,
        sigma_z=sigma_z,
        c_padded=c,
        valid_c=c if valid_c is None else valid_c,
    )
    return pl.pallas_call(
        kern,
        grid=(b // bm,),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
    )(z.astype(jnp.float32), seed)
