"""Portable counter-based PRNG used inside Pallas kernels and jnp oracles.

The RACA hardware gets its entropy for free from device thermal noise; the
TPU simulation must synthesize it.  `pltpu.prng_random_bits` has no CPU
interpret-mode implementation, so we use a stateless counter-based hash
(splitmix32 finalizer) built from plain uint32 jnp ops that lower identically
inside Pallas TPU kernels, Pallas interpret mode, and the pure-jnp reference
oracles — giving *bit-exact* kernel-vs-oracle tests even on the stochastic
paths.

Statistical quality is simulation-grade (passes mean/var/correlation checks
in tests), not cryptographic — the same standing as the physical noise it
models.  Noise is fully determined by (seed, element counter), so runs are
reproducible and restart-safe regardless of sharding or block shape.
"""

from __future__ import annotations

import jax.numpy as jnp

# numpy-uint32 scalar constants: these become jaxpr *Literals* (inlined), so
# Pallas kernels can use them — jnp array constants would be captured consts,
# which pallas_call rejects, and bare Python ints > 2^31-1 overflow the weak
# int32 type.
import numpy as _np

_GOLDEN = _np.uint32(0x9E3779B9)
_M1 = _np.uint32(0x7FEB352D)
_M2 = _np.uint32(0x846CA68B)


def hash_u32(x: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """splitmix32-style avalanche hash of a uint32 counter with a seed."""
    x = x.astype(jnp.uint32) + seed.astype(jnp.uint32) * _GOLDEN
    x = (x ^ (x >> 16)) * _M1
    x = (x ^ (x >> 15)) * _M2
    x = x ^ (x >> 16)
    return x


def uniform01(bits: jnp.ndarray) -> jnp.ndarray:
    """uint32 bits -> float32 uniform in the open interval (0, 1).

    Uses the top 24 bits (exact in f32) plus a half-ulp offset so log() is
    always finite."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    ) + jnp.float32(1.0 / (1 << 25))


def gaussian(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Standard normal per counter element via Box-Muller.

    ``idx`` is a uint32 counter array (globally unique per logical element),
    ``seed`` a uint32 scalar.  Two decorrelated streams come from hashing the
    same counter with offset seeds."""
    seed = seed.astype(jnp.uint32)
    b1 = hash_u32(idx, seed)
    b2 = hash_u32(idx, seed + _GOLDEN)
    u1 = uniform01(b1)
    u2 = uniform01(b2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979) * u2)


def uniform(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Uniform (0,1) per counter element."""
    return uniform01(hash_u32(idx, seed.astype(jnp.uint32)))


def key_to_seed(key) -> jnp.ndarray:
    """Fold a jax PRNG key into a uint32 kernel seed."""
    import jax

    data = jax.random.key_data(key).reshape(-1).astype(jnp.uint32)
    s = data[0]
    for i in range(1, data.shape[0]):
        s = s * _GOLDEN + data[i]
    return s
