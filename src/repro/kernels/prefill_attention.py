"""Prefix-aware chunked-prefill attention kernel.

The partial-prefix serving path computes only a prompt's *suffix* (the
blocks not already resident in the paged pool) and lets those suffix
queries attend into the shared prefix pages directly — the prefill-side
analogue of the paper's thesis: delete work whose result is already
materialized in the array (here: K/V of a shared system prompt) instead of
regenerating it through the full pipeline.

Shape contract: ONE request per call.  The query tile is the whole suffix
chunk (S, H, Dh), resident in VMEM; keys/values are gathered block by
block from the paged pool:

  * the request's block table (scalar-prefetched into SMEM) drives the
    BlockSpec index map, so each grid step DMAs ONE (block_size,) K/V page
    from HBM — shared prefix pages and the chunk's own freshly written
    pages go through the same path;
  * queries carry their ABSOLUTE positions (``q0 + i``), so the causal /
    local mask is exact even though the tile starts mid-prompt;
  * the flash-attention recurrence (running max / denom / accumulator)
    lives in VMEM scratch across the sequential block axis;
  * blocks entirely beyond the last query position skip their compute
    AND accumulator update via pl.when (the page DMA itself still runs —
    the grid covers the full table width).

int8 pools ride the same fused-dequant scheme as the decode kernel
(kernels/paged_attention.py): pages DMA int8 codes plus per-(page,
slot-in-page, head) f32 scale planes, scores pick up ``k_scale/127`` and
softmax weights ``v_scale/127`` inside VMEM — a dequantized page never
exists anywhere.

Grid: (W,), sequential — the accumulator carries across the request's
blocks.  The pure-jnp oracle is kernels/ref.py:prefill_attention_ref; CPU
tests run this kernel in interpret mode (see compat.py), and off TPU the
serving engine's bf16 path uses the gather + attend_full jnp route in
models/attention.py (bit-identical to the dense monolithic prefill).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

NEG_INF = -2.0e38


def _kernel(
    tbl_ref,   # (W,) int32 SMEM (scalar prefetch): the request's block table
    q0_ref,    # (1,) int32 SMEM (scalar prefetch): first query position
    q_ref,     # (S, H, Dh) f32 — the whole suffix chunk's queries
    k_ref,     # (1, bs, Hkv, Dh) f32 (or int8 codes) — page tbl[w]
    v_ref,     # (1, bs, Hkv, Dh) f32 (or int8 codes)
    *rest,     # int8: ks_ref, vs_ref (1, bs, Hkv) f32, then o/m/l/acc refs
    nw: int,
    bs: int,
    hkv: int,
    kind: str,
    local_window: int,
    softcap: float,
    int8: bool,
):
    if int8:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    w = pl.program_id(0)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = q0_ref[0]
    s, h, dh = q_ref.shape
    g = h // hkv

    # A block whose first position is beyond the LAST query position holds
    # no attendable keys for this chunk: skip its DMA'd page entirely.
    @pl.when(w * bs <= q0 + s - 1)
    def _block():
        q = q_ref[...]                      # (S, H, Dh)
        qg = (
            q.reshape(s, hkv, g, dh).transpose(1, 2, 0, 3).astype(jnp.float32)
            * jnp.float32(dh**-0.5)
        )                                   # (Hkv, G, S, Dh)
        k = k_ref[0].astype(jnp.float32)    # (bs, Hkv, Dh)
        v = v_ref[0].astype(jnp.float32)
        sc = jnp.einsum(
            "kgsd,tkd->kgst", qg, k, preferred_element_type=jnp.float32
        )                                   # (Hkv, G, S, bs)
        if int8:
            # fused dequant: int8 codes crossed HBM; the scale multiplies
            # the SCORES in VMEM (factors out of the Dh contraction)
            ks = ks_ref[0].astype(jnp.float32) * jnp.float32(1.0 / 127.0)
            sc = sc * ks.transpose(1, 0)[:, None, None, :]
        if softcap > 0.0:
            sc = jnp.tanh(sc / jnp.float32(softcap)) * jnp.float32(softcap)
        # absolute positions: query i sits at q0 + i, key t at w·bs + t
        qpos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, s, bs), 2) + q0
        kpos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, s, bs), 3) + w * bs
        ok = kpos <= qpos
        if kind == "local":
            ok &= kpos > (qpos - local_window)
        sc = sc + jnp.where(ok, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
        if int8:
            # v-side dequant folds into the softmax numerator weights; the
            # denominator keeps the raw pexp sums (scaled numerator /
            # unscaled denominator, same as the decode kernel)
            vs = vs_ref[0].astype(jnp.float32) * jnp.float32(1.0 / 127.0)
            pv = pexp * vs.transpose(1, 0)[:, None, None, :]
        else:
            pv = pexp
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
            "kgst,tkd->kgsd", pv, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(w == nw - 1)
    def _readout():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = out.transpose(2, 0, 1, 3).reshape(s, h, dh)


def paged_prefill_attention_pallas(
    q: jax.Array,        # (S, H, Dh) f32 — the suffix chunk's queries
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) f32 (or int8 codes) block pool
    v_pages: jax.Array,
    table: jax.Array,    # (W,) int32 page ids; <0 treated as page 0
    q0: jax.Array,       # () int32 absolute position of the first query
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
    interpret: bool | object = False,
) -> jax.Array:
    """Returns the (S, H, Dh) attention readout of a suffix chunk over the
    request's blocks (shared prefix pages + its own freshly written pages).

    Pass int8 ``k_pages``/``v_pages`` together with ``k_scale``/``v_scale``
    planes to run the fused-dequant path (int8 page DMA, scaling in VMEM).
    """
    s, h, dh = q.shape
    n_pages, bs, hkv, dh2 = k_pages.shape
    assert dh == dh2 and h % hkv == 0, (q.shape, k_pages.shape)
    int8 = k_scale is not None
    if int8:
        assert v_scale is not None
        assert k_scale.shape == (n_pages, bs, hkv), k_scale.shape
    nw = table.shape[0]
    kern = functools.partial(
        _kernel,
        nw=nw,
        bs=bs,
        hkv=hkv,
        kind=kind,
        local_window=local_window,
        softcap=softcap,
        int8=int8,
    )
    page_map = lambda wi, tbl, p0: (jnp.maximum(tbl[wi], 0), 0, 0, 0)
    scale_map = lambda wi, tbl, p0: (jnp.maximum(tbl[wi], 0), 0, 0)
    in_specs = [
        pl.BlockSpec((s, h, dh), lambda wi, tbl, p0: (0, 0, 0)),
        pl.BlockSpec((1, bs, hkv, dh), page_map),
        pl.BlockSpec((1, bs, hkv, dh), page_map),
    ]
    # keep int8 codes int8 on the wire — halving the page DMA bytes is the
    # point; everything else is normalized to f32 before the call
    operands = [
        table.astype(jnp.int32),
        jnp.asarray(q0, jnp.int32).reshape((1,)),
        q.astype(jnp.float32),
        k_pages if int8 else k_pages.astype(jnp.float32),
        v_pages if int8 else v_pages.astype(jnp.float32),
    ]
    if int8:
        in_specs += [
            pl.BlockSpec((1, bs, hkv), scale_map),
            pl.BlockSpec((1, bs, hkv), scale_map),
        ]
        operands += [
            k_scale.astype(jnp.float32),
            v_scale.astype(jnp.float32),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nw,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((s, h, dh), lambda wi, tbl, p0: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, h // hkv, s), jnp.float32),
            pltpu.VMEM((hkv, h // hkv, s), jnp.float32),
            pltpu.VMEM((hkv, h // hkv, s, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, h, dh), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            # W must stay sequential: the scratch accumulator carries the
            # online-softmax state across the request's blocks.
            dimension_semantics=("arbitrary",),
        ),
    )(*operands)
