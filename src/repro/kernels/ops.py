"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * shape padding to MXU-aligned blocks (and un-padding),
  * jax PRNG key → kernel seed derivation,
  * straight-through / QAT gradients via custom_vjp,
  * backend dispatch, two layers deep:
      - the PUBLIC entry points (crossbar_mac, wta_counts, stoch_round*,
        paged_attention*) route through the active device backend
        (`repro.kernels.backend` — Sim by default, the seam for
        hardware-in-the-loop later);
      - the Sim implementations (`*_sim` below) then pick compiled Pallas
        on TPU, `pltpu.InterpretParams` emulation on CPU (tests), or the
        pure-jnp oracle where a caller asks for it.

All wrappers accept arbitrary leading batch dims on ``x``.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import backend as _backend
from . import crossbar_mac as _cb
from . import prng, ref
from . import stoch_round as _sr
from . import wta_kernel as _wta
from repro.core.physics import BOLTZMANN_K, PROBIT_SCALE


def _interpret_mode():
    from .compat import interpret_mode

    return interpret_mode()


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _seed_from_key(key: jax.Array) -> jax.Array:
    s = prng.key_to_seed(key)
    return jax.lax.bitcast_convert_type(s, jnp.int32).reshape((1,))


def _qstep(dp) -> float:
    return (dp.w_max - dp.w_min) / max(dp.n_levels - 1, 1)


def _noise_params(dp, k_rows: int) -> tuple:
    return (
        4.0 * BOLTZMANN_K * dp.temperature * dp.delta_f,
        dp.g0,
        dp.g_ref,
        dp.v_read,
        float(k_rows),
    )


# ---------------------------------------------------------------------------
# crossbar_mac: fused analog matmul (+ optional stochastic binarization).
# ---------------------------------------------------------------------------


def _range_scale(w):
    """Per-layer dynamic-range scale s = max|W| (see core.analog)."""
    return jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(w)), 1e-6))


def _crossbar_fwd_impl(x2d, w, seed_arr, cfg, binarize, bm, bn, bk, interp):
    m, k = x2d.shape
    n = w.shape[1]
    dp = cfg.device
    if cfg.calibrated:
        # dynamic-range mapping: devices hold w/s; the comparator slope (via
        # per-layer V_r) absorbs s: sigma_norm = 1.702 / (beta·s) realizes
        # P = sigmoid(beta·s·z_norm) = sigmoid(beta·z).
        s = _range_scale(w)
        w_in = w / s
        if binarize:
            sigma = jnp.float32(PROBIT_SCALE) / (cfg.beta * s)
        else:
            sigma = jnp.float32(cfg.linear_sigma)  # high-SNR linear read
    else:
        s = jnp.float32(1.0)
        w_in = w
        sigma = jnp.float32(PROBIT_SCALE / cfg.beta)  # unused (physical)
    params = jnp.concatenate(
        [seed_arr, jax.lax.bitcast_convert_type(sigma, jnp.int32).reshape(1)]
    )
    xp = _pad_to(_pad_to(x2d, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_in, bk, 0), bn, 1)
    out = _cb.crossbar_mac_pallas(
        xp,
        wp,
        params,
        binarize=binarize,
        physical_noise=not cfg.calibrated,
        noise_params=_noise_params(dp, k),
        quantize=cfg.quantize,
        qstep=_qstep(dp),
        w_min=dp.w_min,
        w_max=dp.w_max,
        bm=bm,
        bn=bn,
        bk=bk,
        valid_k=k,
        interpret=interp,
    )
    out = out[:m, :n]
    if not binarize and cfg.calibrated:
        out = out * s  # scale normalized linear readout back
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _crossbar_mac_core(x2d, w, seed_arr, cfg, binarize):
    interp = _interpret_mode()
    return _crossbar_fwd_impl(
        x2d, w, seed_arr, cfg, binarize, _cb.DEF_BM, _cb.DEF_BN, _cb.DEF_BK,
        interp,
    )


def _crossbar_fwd(x2d, w, seed_arr, cfg, binarize):
    y = _crossbar_mac_core(x2d, w, seed_arr, cfg, binarize)
    return y, (x2d, w)


def _crossbar_bwd(cfg, binarize, res, g):
    """QAT/STE backward.

    binarize=True : y ~ Bern(sigmoid(beta z)); STE surrogate E[y] gives
                    dz = g · beta · p(1-p)   with p recomputed (remat).
    binarize=False: y = z + noise (noise treated as additive const) => dz = g.
    Quantizer: straight-through.  With dynamic-range normalization the clip
    never saturates (w/s ∈ [-1,1]); the physical path keeps the clip mask.
    """
    x2d, w = res
    dp = cfg.device
    wq = w
    if cfg.quantize:
        step = _qstep(dp)
        if cfg.calibrated:
            s = _range_scale(w)
            wn = jnp.clip(w / s, dp.w_min, dp.w_max)
            wq = s * (
                jnp.round((wn - dp.w_min) * jnp.float32(1.0 / step)) * step
                + dp.w_min
            )
        else:
            wq = jnp.clip(w, dp.w_min, dp.w_max)
            wq = (
                jnp.round((wq - dp.w_min) * jnp.float32(1.0 / step)) * step
                + dp.w_min
            )
    if binarize:
        z = x2d @ wq
        p = jax.nn.sigmoid(cfg.beta * z)
        dz = g * cfg.beta * p * (1.0 - p)
    else:
        dz = g
    dx = dz @ wq.T
    dw = x2d.T @ dz
    if cfg.quantize and not cfg.calibrated:
        dw = dw * ((w >= dp.w_min) & (w <= dp.w_max)).astype(dw.dtype)
    return dx, dw, None


_crossbar_mac_core.defvjp(_crossbar_fwd, _crossbar_bwd)


def crossbar_mac(
    x: jax.Array,
    w: jax.Array,
    key: jax.Array,
    cfg: Any,
    binarize: bool = True,
) -> jax.Array:
    """Fused RACA matmul.  x: (..., K) f32, w: (K, N) f32 → (..., N) f32.

    Dispatches through the active device backend (Sim routes to
    :func:`crossbar_mac_sim`, i.e. today's Pallas/interpret math)."""
    return _backend.get_backend().crossbar_mac(x, w, key, cfg, binarize)


def crossbar_mac_sim(
    x: jax.Array,
    w: jax.Array,
    key: jax.Array,
    cfg: Any,
    binarize: bool = True,
) -> jax.Array:
    """Sim-backend implementation (the pre-seam wrapper, bit-identical)."""
    lead = x.shape[:-1]
    x2d = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    y = _crossbar_mac_core(
        x2d, w.astype(jnp.float32), _seed_from_key(key), cfg, binarize
    )
    return y.reshape(lead + (w.shape[1],))


def crossbar_mac_reference(
    x: jax.Array, w: jax.Array, key: jax.Array, cfg: Any, binarize: bool = True
) -> jax.Array:
    """Same padding/seed/normalization pipeline, oracle math — for
    kernel-vs-ref tests."""
    lead = x.shape[:-1]
    x2d = x.reshape((-1, x.shape[-1])).astype(jnp.float32)
    m, k = x2d.shape
    n = w.shape[1]
    wf = w.astype(jnp.float32)
    dp = cfg.device
    if cfg.calibrated:
        s = _range_scale(wf)
        w_in = wf / s
        if binarize:
            sigma = jnp.float32(PROBIT_SCALE) / (cfg.beta * s)
        else:
            sigma = jnp.float32(cfg.linear_sigma)
    else:
        s = jnp.float32(1.0)
        w_in = wf
        sigma = jnp.float32(PROBIT_SCALE / cfg.beta)
    xp = _pad_to(_pad_to(x2d, _cb.DEF_BM, 0), _cb.DEF_BK, 1)
    wp = _pad_to(_pad_to(w_in, _cb.DEF_BK, 0), _cb.DEF_BN, 1)
    out = ref.crossbar_mac_ref(
        xp,
        wp,
        prng.key_to_seed(key),
        binarize=binarize,
        physical_noise=not cfg.calibrated,
        sigma_z=sigma,
        noise_params=_noise_params(dp, k),
        quantize=cfg.quantize,
        qstep=_qstep(dp),
        w_min=dp.w_min,
        w_max=dp.w_max,
        valid_k=k,
    )
    out = out[:m, :n]
    if not binarize and cfg.calibrated:
        out = out * s
    return out.reshape(lead + (n,))


# ---------------------------------------------------------------------------
# Canary probe: fixed known-answer crossbar MAC for drift detection.
# ---------------------------------------------------------------------------

_CANARY_ROWS, _CANARY_COLS = 128, 8


@functools.lru_cache(maxsize=1)
def _canary_operands():
    import numpy as np

    rng = np.random.default_rng(0xCA9A31)
    x = rng.uniform(-1.0, 1.0, (1, _CANARY_ROWS)).astype(np.float32)
    w = rng.uniform(-1.0, 1.0, (_CANARY_ROWS, _CANARY_COLS)).astype(
        np.float32
    )
    return x, w


@functools.lru_cache(maxsize=1)
def _canary_cfg():
    from repro.core.analog import AnalogConfig

    # Unquantized calibrated linear read: the healthy answer is exactly
    # x @ w plus a small zero-mean read noise, so drift/stuck-at shifts
    # are separable from the noise floor by a relative-error threshold.
    return AnalogConfig(
        mode="analog_linear", quantize=False, calibrated=True,
        linear_sigma=0.01,
    )


def canary_expected():
    """Host-side known answer of the canary MAC (float32 ndarray)."""
    x, w = _canary_operands()
    return x @ w


def canary_mac(key: jax.Array) -> jax.Array:
    """Fire the canary: a fixed (1, 128) x (128, 8) linear crossbar read
    through the ACTIVE device backend.

    On a healthy backend the result is ``canary_expected()`` plus
    ~linear_sigma read noise; conductance drift scales it multiplicatively
    and stuck-at cells shift it, so a relative-error check against the
    known answer detects substrate degradation without touching live
    traffic.  Traced + jitted by the serving engine, and rebuilt alongside
    the other entry points when the backend's fault_version bumps."""
    x, w = _canary_operands()
    return crossbar_mac(
        jnp.asarray(x), jnp.asarray(w), key, _canary_cfg(), binarize=False
    )


# ---------------------------------------------------------------------------
# WTA vote counting.
# ---------------------------------------------------------------------------


def wta_counts(
    z: jax.Array,
    key: jax.Array,
    *,
    n_trials: int,
    vth0: float,
    sigma_z: float,
) -> jax.Array:
    """Winner counts over T WTA trials.  z: (..., C) → counts (..., C).

    Inference-path readout: gradients are stopped (the training surrogate is
    softmax cross-entropy on the pre-activations, as in the paper).
    Dispatches through the active device backend."""
    return _backend.get_backend().wta_counts(
        z, key, n_trials=n_trials, vth0=vth0, sigma_z=sigma_z
    )


def wta_counts_sim(
    z: jax.Array,
    key: jax.Array,
    *,
    n_trials: int,
    vth0: float,
    sigma_z: float,
) -> jax.Array:
    """Sim-backend implementation (the pre-seam wrapper, bit-identical)."""
    lead = z.shape[:-1]
    c = z.shape[-1]
    z2d = z.reshape((-1, c)).astype(jnp.float32)
    bm = _wta.DEF_BM
    zp = _pad_to(_pad_to(z2d, bm, 0), 128, 1)
    out = _wta.wta_counts_pallas(
        jax.lax.stop_gradient(zp),
        _seed_from_key(key),
        n_trials=n_trials,
        vth0=vth0,
        sigma_z=sigma_z,
        valid_c=c,
        bm=bm,
        interpret=_interpret_mode(),
    )
    return out[: z2d.shape[0], :c].reshape(lead + (c,))


def wta_counts_reference(
    z: jax.Array, key: jax.Array, *, n_trials: int, vth0: float, sigma_z: float
) -> jax.Array:
    lead = z.shape[:-1]
    c = z.shape[-1]
    z2d = z.reshape((-1, c)).astype(jnp.float32)
    bm = _wta.DEF_BM
    zp = _pad_to(_pad_to(z2d, bm, 0), 128, 1)
    out = ref.wta_counts_ref(
        zp,
        prng.key_to_seed(key),
        n_trials=n_trials,
        vth0=vth0,
        sigma_z=sigma_z,
        valid_c=c,
        bm=bm,
    )
    return out[: z2d.shape[0], :c].reshape(lead + (c,))


# ---------------------------------------------------------------------------
# Paged attention (serving decode path).
# ---------------------------------------------------------------------------


def paged_attention(
    q: jax.Array,        # (B, H, Dh)
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (B, W) int32
    pos: jax.Array,      # (B,) int32
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Block-table decode attention, dispatched through the active device
    backend (Sim routes to :func:`paged_attention_sim`)."""
    return _backend.get_backend().paged_attention(
        q, k_pages, v_pages, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=k_scale, v_scale=v_scale,
    )


def paged_attention_sim(
    q: jax.Array,        # (B, H, Dh)
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (B, W) int32
    pos: jax.Array,      # (B,) int32
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Block-table decode attention: compiled Pallas kernel on TPU, the
    pure-jnp oracle elsewhere.

    With int8 ``k_pages``/``v_pages`` the per-(page, slot-in-page, head)
    ``k_scale``/``v_scale`` planes ride along and dequantization is fused
    into the score/value math — int8 blocks are what crosses HBM; no
    dequantized cache is ever materialized.

    Unlike the crossbar kernels, the off-TPU fallback is the oracle rather
    than interpret-mode emulation: this sits in the serving engine's
    per-token hot loop, where interpret mode would bury the very latency
    the paged layout removes.  Kernel-vs-oracle agreement is pinned by
    tests/test_kernels.py (interpret mode on small shapes)."""
    from . import paged_attention as _pa

    if jax.default_backend() != "tpu":
        return ref.paged_attention_ref(
            q, k_pages, v_pages, table, pos,
            kind=kind, local_window=local_window, softcap=softcap,
            k_scale=k_scale, v_scale=v_scale,
        )
    return _pa.paged_attention_pallas(
        q, k_pages, v_pages, table, pos,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=k_scale, v_scale=v_scale,
        interpret=False,
    )


def paged_prefill_attention(
    q: jax.Array,        # (S, H, Dh) — one request's suffix-chunk queries
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (W,) int32 — the request's block-table row
    q0: jax.Array,       # () int32 absolute position of the first query
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Prefix-aware chunked-prefill attention, dispatched through the
    active device backend (Sim routes to
    :func:`paged_prefill_attention_sim`)."""
    return _backend.get_backend().paged_prefill_attention(
        q, k_pages, v_pages, table, q0,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=k_scale, v_scale=v_scale,
    )


def paged_prefill_attention_sim(
    q: jax.Array,        # (S, H, Dh) — one request's suffix-chunk queries
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) — cache dtype or int8 codes
    v_pages: jax.Array,
    table: jax.Array,    # (W,) int32 — the request's block-table row
    q0: jax.Array,       # () int32 absolute position of the first query
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Prefix-aware chunked-prefill attention: the suffix chunk's queries
    attend into every block of the request's table — shared prefix pages
    included — at their absolute positions.  Compiled Pallas kernel on
    TPU, the pure-jnp oracle elsewhere (interpret mode would bury the
    prefill latency the suffix path removes; kernel-vs-oracle agreement is
    pinned by tests/test_kernels.py).  int8 pools fuse dequant into the
    score/value math exactly like :func:`paged_attention`.

    NOTE: the serving engine's off-TPU bf16 path does NOT come through
    here — it uses the gather + attend_full route in models/attention.py,
    whose numerics are bit-identical to the dense monolithic prefill (the
    dense-vs-paged equivalence oracle).  This dispatch serves the TPU hot
    path and the int8 fused-dequant math on every backend."""
    from . import prefill_attention as _pf

    if jax.default_backend() != "tpu":
        return ref.prefill_attention_ref(
            q, k_pages, v_pages, table, q0,
            kind=kind, local_window=local_window, softcap=softcap,
            k_scale=k_scale, v_scale=v_scale,
        )
    return _pf.paged_prefill_attention_pallas(
        q, k_pages, v_pages, table, q0,
        kind=kind, local_window=local_window, softcap=softcap,
        k_scale=k_scale, v_scale=v_scale,
        interpret=False,
    )


# ---------------------------------------------------------------------------
# Stochastic rounding.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _stoch_round_core(x2d, seed_arr, step, lo, hi):
    xp = _pad_to(_pad_to(x2d, _sr.DEF_BM, 0), _sr.DEF_BN, 1)
    out = _sr.stoch_round_pallas(
        xp, seed_arr, step=step, lo=lo, hi=hi, interpret=_interpret_mode()
    )
    return out[: x2d.shape[0], : x2d.shape[1]]


def _sr_fwd(x2d, seed_arr, step, lo, hi):
    return _stoch_round_core(x2d, seed_arr, step, lo, hi), x2d


def _sr_bwd(step, lo, hi, x2d, g):
    mask = ((x2d >= lo) & (x2d <= hi)).astype(g.dtype)
    return g * mask, None


_stoch_round_core.defvjp(_sr_fwd, _sr_bwd)


def stoch_round(
    x: jax.Array, key: jax.Array, *, step: float, lo: float, hi: float
) -> jax.Array:
    """Unbiased stochastic rounding onto {lo + k·step}; STE gradient.
    Dispatches through the active device backend."""
    return _backend.get_backend().stoch_round(x, key, step=step, lo=lo, hi=hi)


def stoch_round_sim(
    x: jax.Array, key: jax.Array, *, step: float, lo: float, hi: float
) -> jax.Array:
    """Sim-backend implementation (the pre-seam wrapper, bit-identical)."""
    shape = x.shape
    x2d = x.reshape((-1, shape[-1])).astype(jnp.float32)
    y = _stoch_round_core(x2d, _seed_from_key(key), step, lo, hi)
    return y.reshape(shape)


def stoch_round_reference(
    x: jax.Array, key: jax.Array, *, step: float, lo: float, hi: float
) -> jax.Array:
    shape = x.shape
    x2d = x.reshape((-1, shape[-1])).astype(jnp.float32)
    xp = _pad_to(_pad_to(x2d, _sr.DEF_BM, 0), _sr.DEF_BN, 1)
    out = ref.stoch_round_ref(
        xp, prng.key_to_seed(key), step=step, lo=lo, hi=hi
    )
    return out[: x2d.shape[0], : x2d.shape[1]].reshape(shape)


def stoch_round_serving(
    x: jax.Array, seed: jax.Array, *, step: float, lo: float, hi: float
) -> jax.Array:
    """Serving-hot-path stochastic rounding, dispatched through the active
    device backend (Sim routes to :func:`stoch_round_serving_sim`)."""
    return _backend.get_backend().stoch_round_serving(
        x, seed, step=step, lo=lo, hi=hi
    )


def stoch_round_serving_sim(
    x: jax.Array, seed: jax.Array, *, step: float, lo: float, hi: float
) -> jax.Array:
    """Stochastic rounding for the serving hot path, seeded by a raw
    uint32 counter-PRNG seed (traced scalar) instead of a jax PRNG key.

    Backend dispatch mirrors :func:`paged_attention`: the compiled Pallas
    kernel on TPU, the pure-jnp oracle elsewhere — interpret-mode emulation
    would bury the per-token decode latency this feeds.  Kernel and oracle
    share the counter PRNG, so the rounding decisions are bit-identical
    across backends for a given (seed, element) pair."""
    shape = x.shape
    x2d = x.reshape((-1, shape[-1])).astype(jnp.float32)
    xp = _pad_to(_pad_to(x2d, _sr.DEF_BM, 0), _sr.DEF_BN, 1)
    seed_u = jnp.asarray(seed).astype(jnp.uint32)
    if jax.default_backend() != "tpu":
        out = ref.stoch_round_ref(xp, seed_u, step=step, lo=lo, hi=hi)
    else:
        seed_arr = jax.lax.bitcast_convert_type(seed_u, jnp.int32).reshape(1)
        out = _sr.stoch_round_pallas(
            xp, seed_arr, step=step, lo=lo, hi=hi, interpret=False
        )
    return out[: x2d.shape[0], : x2d.shape[1]].reshape(shape)


def quantize_kv_int8(
    x: jax.Array, seed: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization with unbiased stochastic rounding.

    ``x`` is (..., Dh); returns (codes int8 (..., Dh), scale f32 (...,)).
    The scale is the row's max |value| so codes span the full [-127, 127]
    grid, and each element is stochastically rounded to an adjacent integer
    level (``E[codes] = x / scale * 127``) — the paper's conductance-
    programming primitive (§II-B, kernels/stoch_round) applied to the KV
    cache, so quantized cache writes stay unbiased exactly like programming
    weights onto discrete device levels.  Dequantization is never
    materialized: attention multiplies *scores* by ``scale / 127`` (see
    paged_attention / models.attention.attend_one_token)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-6)
    t = xf / scale[..., None] * 127.0
    q = stoch_round_serving(t, seed, step=1.0, lo=-127.0, hi=127.0)
    return q.astype(jnp.int8), scale


def quantize_kv_pair_int8(
    k: jax.Array, v: jax.Array, seed: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Quantize a K/V pair from ONE seed with decorrelated rounding streams.

    The v stream is offset by the golden-ratio constant so k and v never
    share per-element rounding draws (identical draws would correlate
    their quantization errors and bias attention readouts).  Both int8
    cache-write paths (prefill insert in launch/specs.py, decode write in
    models/attention.py) go through here so the offset cannot drift.

    Returns (k_codes, k_scale, v_codes, v_scale)."""
    seed_u = jnp.asarray(seed).astype(jnp.uint32)
    k8, ks = quantize_kv_int8(k, seed_u)
    v8, vs = quantize_kv_int8(v, seed_u + jnp.uint32(0x9E3779B9))
    return k8, ks, v8, vs
