"""Paged-attention decode kernel: block-table gather + online-softmax.

Serving-side analogue of the paper's memory-path restructuring (removing
the DAC/ADC round-trips): the decode hot spot is not the MAC but the HBM
traffic of re-reading a dense (max_len,) KV window per emitted token.  This
kernel attends over exactly the blocks a request has filled:

  * the block table (scalar-prefetched into SMEM) drives the BlockSpec
    index_map, so each grid step DMAs ONE (block_size,) KV page from HBM —
    pages the request never touched are never fetched;
  * the flash-attention recurrence (running max / denom / accumulator)
    lives in VMEM scratch across the sequential block axis;
  * blocks entirely beyond the request's position are skipped via pl.when.

int8 pools halve that HBM traffic again: K/V pages hold int8 codes plus a
per-(page, slot-in-page, head) f32 scale plane, and dequantization is fused
into the kernel — the DMA moves int8 bytes, scores are multiplied by
``k_scale/127`` and softmax weights by ``v_scale/127`` inside VMEM (the
same scores-not-cache trick as the dense int8 path in models/attention.py),
so a dequantized page never exists anywhere.

Grid: (B, W) with W = table width (blocks per slot), W innermost and
sequential — the accumulator carries across a slot's blocks.

The pure-jnp oracle is kernels/ref.py:paged_attention_ref; CPU tests run
this kernel in interpret mode (see compat.py) and the serving engine off
TPU uses the gather + shared-attend jnp path in models/attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

NEG_INF = -2.0e38


def _kernel(
    tbl_ref,   # (B, W) int32 SMEM (scalar prefetch): block table
    pos_ref,   # (B,) int32 SMEM (scalar prefetch): last valid position
    q_ref,     # (1, H, Dh) f32
    k_ref,     # (1, bs, Hkv, Dh) f32 (or int8 codes) — page tbl[b, w]
    v_ref,     # (1, bs, Hkv, Dh) f32 (or int8 codes)
    *rest,     # int8: ks_ref, vs_ref (1, bs, Hkv) f32, then o/m/l/acc refs
    nw: int,
    bs: int,
    hkv: int,
    kind: str,
    local_window: int,
    softcap: float,
    int8: bool,
):
    if int8:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = pos_ref[b]

    # A block whose first position is beyond ``pos`` holds no valid keys:
    # skip its DMA'd page entirely (compute AND accumulator update).
    @pl.when(w * bs <= p)
    def _block():
        q = q_ref[0]                       # (H, Dh)
        h, dh = q.shape
        g = h // hkv
        qg = q.reshape(hkv, g, dh).astype(jnp.float32) * jnp.float32(
            dh**-0.5
        )
        k = k_ref[0].astype(jnp.float32)   # (bs, Hkv, Dh)
        v = v_ref[0].astype(jnp.float32)
        sc = jnp.einsum(
            "kgd,tkd->kgt", qg, k, preferred_element_type=jnp.float32
        )
        if int8:
            # fused dequant: int8 codes crossed HBM; the scale multiplies
            # the SCORES in VMEM (factors out of the Dh contraction)
            ks = ks_ref[0].astype(jnp.float32) * jnp.float32(1.0 / 127.0)
            sc = sc * ks.transpose(1, 0)[:, None, :]   # (Hkv, 1, bs)
        if softcap > 0.0:
            sc = jnp.tanh(sc / jnp.float32(softcap)) * jnp.float32(softcap)
        kpos = (
            jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
            + w * bs
        )
        ok = kpos <= p
        if kind == "local":
            ok &= kpos > (p - local_window)
        sc = sc + jnp.where(ok, 0.0, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1)
        if int8:
            # v-side dequant folds into the softmax numerator weights; the
            # denominator keeps the raw pexp sums, exactly like the dense
            # int8 path (scaled numerator / unscaled denominator)
            vs = vs_ref[0].astype(jnp.float32) * jnp.float32(1.0 / 127.0)
            pv = pexp * vs.transpose(1, 0)[:, None, :]
        else:
            pv = pexp
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
            "kgt,tkd->kgd", pv, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(w == nw - 1)
    def _readout():
        _, h, dh = o_ref.shape
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(h, dh)


def paged_attention_pallas(
    q: jax.Array,        # (B, H, Dh) f32 — one query token per slot
    k_pages: jax.Array,  # (P, bs, Hkv, Dh) f32 (or int8 codes) block pool
    v_pages: jax.Array,
    table: jax.Array,    # (B, W) int32 page ids; <0 treated as page 0
    pos: jax.Array,      # (B,) int32 last valid key position per slot
    *,
    kind: str = "global",
    local_window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (P, bs, Hkv) f32 for int8 pools
    v_scale: jax.Array | None = None,
    interpret: bool | object = False,
) -> jax.Array:
    """Returns the (B, H, Dh) attention readout over each slot's blocks.

    Pass int8 ``k_pages``/``v_pages`` together with ``k_scale``/``v_scale``
    planes to run the fused-dequant path (int8 page DMA, scaling in VMEM).
    """
    b, h, dh = q.shape
    n_pages, bs, hkv, dh2 = k_pages.shape
    assert dh == dh2 and h % hkv == 0, (q.shape, k_pages.shape)
    int8 = k_scale is not None
    if int8:
        assert v_scale is not None
        assert k_scale.shape == (n_pages, bs, hkv), k_scale.shape
    nw = table.shape[1]
    kern = functools.partial(
        _kernel,
        nw=nw,
        bs=bs,
        hkv=hkv,
        kind=kind,
        local_window=local_window,
        softcap=softcap,
        int8=int8,
    )
    page_map = lambda bi, wi, tbl, ps: (jnp.maximum(tbl[bi, wi], 0), 0, 0, 0)
    scale_map = lambda bi, wi, tbl, ps: (jnp.maximum(tbl[bi, wi], 0), 0, 0)
    in_specs = [
        pl.BlockSpec((1, h, dh), lambda bi, wi, tbl, ps: (bi, 0, 0)),
        pl.BlockSpec((1, bs, hkv, dh), page_map),
        pl.BlockSpec((1, bs, hkv, dh), page_map),
    ]
    # keep int8 codes int8 on the wire — halving the page DMA bytes is the
    # point; everything else is normalized to f32 before the call
    operands = [
        table.astype(jnp.int32),
        pos.astype(jnp.int32),
        q.astype(jnp.float32),
        k_pages if int8 else k_pages.astype(jnp.float32),
        v_pages if int8 else v_pages.astype(jnp.float32),
    ]
    if int8:
        in_specs += [
            pl.BlockSpec((1, bs, hkv), scale_map),
            pl.BlockSpec((1, bs, hkv), scale_map),
        ]
        operands += [
            k_scale.astype(jnp.float32),
            v_scale.astype(jnp.float32),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nw),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, h, dh), lambda bi, wi, tbl, ps: (bi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((hkv, h // hkv), jnp.float32),
            pltpu.VMEM((hkv, h // hkv), jnp.float32),
            pltpu.VMEM((hkv, h // hkv, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            # W must stay sequential (the scratch accumulator carries across
            # a slot's blocks); B revisits scratch only after a full W sweep.
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(*operands)
