"""Stochastic-rounding quantizer kernel.

The conductance-programming primitive of the paper (weights → discrete
device levels, §II-B) — unbiased: E[q(x)] = x.  Reused by the framework for
two distributed-optimization tricks:

  * bf16/int8 optimizer-state rounding (AdamW with low-precision moments),
  * int8 gradient compression with error feedback (optim/compress.py).

Elementwise over a 2-D grid of VMEM blocks; randomness from the same
counter-based PRNG as the other kernels, so results are independent of block
shape and sharding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams as _CompilerParams

from . import prng

DEF_BM, DEF_BN = 256, 512


def _kernel(
    x_ref,
    seed_ref,
    o_ref,
    *,
    n_padded: int,
    step: float,
    lo: float,
    hi: float,
):
    x = jnp.clip(x_ref[...], lo, hi)
    # Multiply by a precomputed f32 reciprocal: a single well-defined f32 op,
    # so the level decision is bit-identical across backends (a division may
    # be rewritten as reciprocal-multiply by some compilers, flipping
    # boundary cases).
    t = (x - lo) * jnp.float32(1.0 / step)
    floor = jnp.floor(t)
    frac = t - floor
    bm, bn = x.shape
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0) + jnp.uint32(
        i * bm
    )
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1) + jnp.uint32(
        j * bn
    )
    idx = rows * jnp.uint32(n_padded) + cols
    u = prng.uniform(idx, seed_ref[0].astype(jnp.uint32))
    q = floor + (u < frac).astype(jnp.float32)
    o_ref[...] = q * jnp.float32(step) + jnp.float32(lo)


def stoch_round_pallas(
    x: jax.Array,
    seed: jax.Array,
    *,
    step: float,
    lo: float,
    hi: float,
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    interpret: bool | object = False,
):
    """x: (M, N) f32 with M % bm == N % bn == 0 (pad in ops.py).
    Stochastically rounds onto the grid {lo + k·step} ∩ [lo, hi]."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    kern = functools.partial(
        _kernel, n_padded=n, step=step, lo=lo, hi=hi
    )
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
    )(x.astype(jnp.float32), seed)
