"""Pluggable device-backend seam behind the analog kernels.

The public wrappers in :mod:`repro.kernels.ops` no longer call their
implementations directly — they dispatch through the process-wide active
:class:`DeviceBackend`.  The split mirrors daffodil-lib's
``Daffodil_Base`` / ``Sim`` / ``Phys`` layering:

* :class:`SimBackend` (the default) routes every kernel to today's
  Pallas/jnp math unchanged — same compiled artifacts, same bits — and
  carries **pure accounting**: host-side tallies of the analog events a
  served workload drives (crossbar MAC tile-reads, comparator decisions,
  input-DAC conversions, stochastic-rounding events), priced by the
  calibrated Table I constants in :mod:`repro.core.cost_model`.
* A future ``PhysBackend`` would override the compute methods with
  hardware-in-the-loop calls (chip driver, FPGA harness) while inheriting
  the same accounting surface — the seam is the point of this module.

Two usage planes, deliberately separate:

1. **Compute dispatch** (trace-time, inside ``jit``): ``ops.crossbar_mac``
   etc. call ``get_backend().crossbar_mac(...)``.  Swapping the process
   backend with :func:`set_backend` swaps the math everywhere at the next
   trace.
2. **Event accounting** (host-side): events cannot be counted inside a
   traced computation, and the counts must not depend on compiled-shape
   padding — so the serving engine owns a private backend instance per
   engine (``ServeConfig.device_backend`` names it) and notes analytical
   multiplicities per entry-point call (see
   ``launch/specs.analog_call_profile``).  Counts are therefore exact
   invariants: ``totals == tokens_computed x per-token shape counts``,
   pinned by tests/test_energy_accounting.py.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

from repro.core import cost_model as CM


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs of the deterministic ReRAM fault model (all zero => no fault).

    The model is applied in *conductance space* at the backend-dispatch
    layer: stuck-at cells pin G to G_min (SA0) / G_max (SA1), conductance
    drift multiplies G by a power-law factor of the host fault clock, and
    the readout knobs perturb the comparator operating point.  Every knob
    at its default leaves :class:`FaultySimBackend` bit-identical to
    :class:`SimBackend` (test-pinned per op family).
    """

    seed: int = 0
    # fraction of cells stuck (split evenly SA0 / SA1), drawn once per
    # weight shape from a PCG64 stream keyed by (seed, shape)
    stuck_rate: float = 0.0
    # power-law drift exponent: G(t) = G(0) · (1 + clock)^(-drift_nu)
    drift_nu: float = 0.0
    # drift multiplier is quantized to this bucket so the engine only
    # retraces when the bucket crosses, not every tick
    drift_quant: float = 0.02
    # cycle-to-cycle read-noise sigma grows by (1 + inflation)
    read_sigma_inflation: float = 0.0
    # additive comparator threshold offset (z-units for WTA readout,
    # output units for the linear crossbar read)
    comparator_offset: float = 0.0
    # physical tile geometry for stuck-at density / retirement
    tile_rows: int = 128
    tile_cols: int = 128


class DeviceBackend:
    """Base: accounting surface (shared) + abstract compute dispatch."""

    name = "base"
    # True when the backend's compute methods differ from the plain sim
    # math — the engine then installs it process-wide around each tick so
    # traces pick the faulty paths up.  Pure-accounting backends leave the
    # process backend alone (no retraces, no cross-engine interference).
    overrides_compute = False

    def __init__(self, model_cfg: Optional[Any] = None):
        self.model_cfg = model_cfg
        if model_cfg is not None:
            self._per_tok = CM.per_token_analog_counts(model_cfg)
            self._per_sample = CM.per_sample_analog_counts(model_cfg)
            self._per_kv_tok = CM.per_kv_token_round_events(model_cfg)
            self._per_redundant = CM.per_redundant_read_counts(model_cfg)
        else:
            zero = CM.AnalogOpCounts()
            self._per_tok = self._per_sample = self._per_kv_tok = zero
            self._per_redundant = zero
        self.reset()

    # -- accounting (host-side, engine-driven) ------------------------------

    def reset(self) -> None:
        self._counts = CM.AnalogOpCounts()
        self._tokens = {"prefill": 0, "decode": 0, "draft": 0}
        self._sample_events = 0
        self._kv_written_tokens = 0
        self._redundant_reads = 0

    def note_call(self, profile: dict) -> None:
        """Record one device entry-point invocation.

        ``profile`` is ``launch/specs.analog_call_profile(...)`` output:
        token-forward multiplicities per kind, sampling events, and
        KV-writing tokens.  Counts accumulate as exact integer multiples
        of the per-token/per-sample/per-KV-token shape counts."""
        fwd = 0
        for kind in ("prefill", "decode", "draft"):
            n = profile[kind]
            self._tokens[kind] += n
            fwd += n
        redundant = profile.get("redundant", 0)
        self._sample_events += profile["samples"]
        self._kv_written_tokens += profile["kv_tokens"]
        self._redundant_reads += redundant
        self._counts = (
            self._counts
            + self._per_tok.scaled(fwd)
            + self._per_sample.scaled(profile["samples"])
            + self._per_kv_tok.scaled(profile["kv_tokens"])
            + self._per_redundant.scaled(redundant)
        )

    def events(self) -> CM.AnalogOpCounts:
        return self._counts

    def tokens_computed(self) -> dict:
        out = dict(self._tokens)
        out["total"] = sum(self._tokens.values())
        return out

    def snapshot(self, published_tokens: int = 0) -> dict:
        """Full accounting report: tallies, per-event shape counts (so a
        validator can re-derive the totals from the artifact alone), and
        Table I pricing under both readout schemes."""
        c = self._counts
        prices = CM.price_counts(c)
        denom = max(published_tokens, 1)

        def scheme(energy_pj: float) -> dict:
            return {
                "energy_pj_gross": energy_pj,
                "energy_pj_per_token": energy_pj / denom,
                "tops_per_w_effective": CM.effective_tops_per_w(
                    c, energy_pj
                ),
            }

        return {
            "backend": self.name,
            "tokens_computed": self.tokens_computed(),
            "tokens_published": published_tokens,
            "sample_events": self._sample_events,
            "kv_written_tokens": self._kv_written_tokens,
            "redundant_read_events": self._redundant_reads,
            "counts": c.as_dict(),
            "per_token_counts": self._per_tok.as_dict(),
            "per_sample_counts": self._per_sample.as_dict(),
            "per_kv_token_counts": self._per_kv_tok.as_dict(),
            "per_redundant_counts": self._per_redundant.as_dict(),
            "raca": scheme(prices["raca_energy_pj"]),
            "adc1b": scheme(prices["adc1b_energy_pj"]),
        }

    # -- compute dispatch (trace-time) --------------------------------------

    def wta_readout_params(self, vth0: float, sigma_z: float):
        """Comparator operating point seen by WTA readout heads.

        Consulted at trace time by ``launch/specs.sample_tokens`` (which
        drives ``core.wta`` directly, not ``ops.wta_counts``) so fault
        backends can perturb the threshold/noise the serving sampler bakes
        into its traces.  Identity on non-faulty backends — the zero-knob
        trace is byte-identical."""
        return vth0, sigma_z

    def crossbar_mac(self, x, w, key, cfg, binarize=True):
        raise NotImplementedError

    def wta_counts(self, z, key, *, n_trials, vth0, sigma_z):
        raise NotImplementedError

    def stoch_round(self, x, key, *, step, lo, hi):
        raise NotImplementedError

    def stoch_round_serving(self, x, seed, *, step, lo, hi):
        raise NotImplementedError

    def paged_attention(self, q, k_pages, v_pages, table, pos, **kw):
        raise NotImplementedError

    def paged_prefill_attention(self, q, k_pages, v_pages, table, q0, **kw):
        raise NotImplementedError


class SimBackend(DeviceBackend):
    """Default backend: today's Pallas/jnp math, accounting only.

    Compute methods delegate to the ``*_sim`` implementations in ops.py
    (imported lazily — ops imports this module at load).  The math is
    bit-identical to the pre-seam wrappers; the recompile-guard and
    byte-identity suites run through this path."""

    name = "sim"

    def crossbar_mac(self, x, w, key, cfg, binarize=True):
        from repro.kernels import ops

        return ops.crossbar_mac_sim(x, w, key, cfg, binarize)

    def wta_counts(self, z, key, *, n_trials, vth0, sigma_z):
        from repro.kernels import ops

        return ops.wta_counts_sim(
            z, key, n_trials=n_trials, vth0=vth0, sigma_z=sigma_z
        )

    def stoch_round(self, x, key, *, step, lo, hi):
        from repro.kernels import ops

        return ops.stoch_round_sim(x, key, step=step, lo=lo, hi=hi)

    def stoch_round_serving(self, x, seed, *, step, lo, hi):
        from repro.kernels import ops

        return ops.stoch_round_serving_sim(x, seed, step=step, lo=lo, hi=hi)

    def paged_attention(self, q, k_pages, v_pages, table, pos, **kw):
        from repro.kernels import ops

        return ops.paged_attention_sim(q, k_pages, v_pages, table, pos, **kw)

    def paged_prefill_attention(self, q, k_pages, v_pages, table, q0, **kw):
        from repro.kernels import ops

        return ops.paged_prefill_attention_sim(
            q, k_pages, v_pages, table, q0, **kw
        )


class FaultySimBackend(SimBackend):
    """Sim math wrapped in a deterministic, seeded ReRAM fault model.

    Faults are applied at the dispatch layer, before/around the unchanged
    sim kernels:

    * **stuck-at cells** — per-shape SA0/SA1 masks drawn once from a PCG64
      stream keyed by ``(seed, shape)``; stuck cells read back as exactly
      ``w_min``/``w_max`` in normalized conductance units (via
      ``physics.weight_from_conductance``), entering traces as constants.
    * **conductance drift** — a multiplicative power-law factor of the
      host-side fault clock (``advance_clock``), quantized to
      ``drift_quant`` buckets; a bucket crossing bumps ``fault_version``
      so the engine knows its compiled artifacts are stale.
    * **read-noise inflation** — calibrated binarized reads see
      ``beta/(1+i)``, calibrated linear reads ``linear_sigma·(1+i)``,
      physical reads a temperature raised by ``(1+i)²`` (σ ∝ √T).
    * **comparator offset** — added to the WTA threshold and to the linear
      crossbar readout.  (The binarized crossbar's internal comparator
      offset is NOT modeled — it lives inside the fused kernel.)

    With every knob at zero each compute method delegates with unmodified
    arguments, so traces — not just values — match :class:`SimBackend`.

    Compiled-artifact staleness: swapping knobs only affects the *next*
    trace.  ``fault_version`` increments on any change that alters traced
    math (drift bucket, retirement, degrade/recover); the serving engine
    checks it each tick and rebuilds its jitted entry points.
    """

    name = "sim_faulty"
    overrides_compute = True

    def __init__(
        self,
        model_cfg: Optional[Any] = None,
        fault: Optional[FaultConfig] = None,
    ):
        self.fault = fault if fault is not None else FaultConfig()
        self._clock = 0
        self._overrides: dict = {}
        self._stuck_maps: dict = {}     # (K, N) -> (sa0, sa1) bool ndarrays
        self._retired: set = set()      # ((K, N), tile_i, tile_j)
        self.fault_version = 0
        self._drift_mult_q = self._drift_mult()
        super().__init__(model_cfg)

    # -- fault-state host API ------------------------------------------------

    def _knob(self, name: str) -> float:
        return self._overrides.get(name, getattr(self.fault, name))

    def _drift_mult(self) -> float:
        nu = self._knob("drift_nu")
        if nu <= 0.0 or self._clock <= 0:
            return 1.0
        m = (1.0 + self._clock) ** (-nu)
        q = self.fault.drift_quant
        if q > 0.0:
            m = max(q, round(m / q) * q)
        return m

    def _refresh(self) -> None:
        new = self._drift_mult()
        if new != self._drift_mult_q:
            self._drift_mult_q = new
            self.fault_version += 1

    def advance_clock(self, n: int = 1) -> None:
        """Tick the host-side fault clock; drift follows the power law."""
        self._clock += int(n)
        self._refresh()

    def degrade(self, clock: Optional[int] = None, **knobs) -> None:
        """Jump the fault clock and/or override readout knobs (injector
        kind ``degrade_device``).  Always bumps ``fault_version``."""
        allowed = {"read_sigma_inflation", "comparator_offset", "drift_nu"}
        bad = sorted(set(knobs) - allowed)
        if bad:
            raise ValueError(
                f"degrade: unknown knob(s) {bad}; allowed: {sorted(allowed)}"
            )
        if clock is not None:
            self._clock = int(clock)
        self._overrides.update(knobs)
        self._drift_mult_q = self._drift_mult()
        self.fault_version += 1

    def recover(self) -> None:
        """Reset the fault clock and drop knob overrides (injector kind
        ``recover_device``).  Tile retirement persists — remapping to a
        spare tile is a physical, one-way operation."""
        self._clock = 0
        self._overrides.clear()
        self._drift_mult_q = self._drift_mult()
        self.fault_version += 1

    def _stuck_masks(self, shape):
        rate = self.fault.stuck_rate
        if rate <= 0.0 or len(shape) != 2:
            return None, None
        if shape not in self._stuck_maps:
            import numpy as np

            rng = np.random.default_rng([self.fault.seed, *shape])
            u = rng.random(shape)
            self._stuck_maps[shape] = (
                u < rate / 2.0,
                (u >= rate / 2.0) & (u < rate),
            )
        return self._stuck_maps[shape]

    def stuck_cell_count(self) -> int:
        return sum(
            int(sa0.sum()) + int(sa1.sum())
            for sa0, sa1 in self._stuck_maps.values()
        )

    @property
    def retired_tiles(self) -> int:
        return len(self._retired)

    def retire_tiles(self, threshold: float) -> int:
        """Retire (remap-to-spare) tiles whose stuck-at density crosses
        ``threshold``: their stuck masks are cleared, so reads behave as a
        healthy spare tile.  Returns the number of newly retired tiles and
        bumps ``fault_version`` when any mask changed."""
        if threshold <= 0.0:
            return 0
        tr, tc = self.fault.tile_rows, self.fault.tile_cols
        newly = 0
        for shape, (sa0, sa1) in self._stuck_maps.items():
            rows, cols = shape
            for ti in range(0, rows, tr):
                for tj in range(0, cols, tc):
                    tile = (shape, ti // tr, tj // tc)
                    if tile in self._retired:
                        continue
                    sl = (slice(ti, ti + tr), slice(tj, tj + tc))
                    cells = sa0[sl].size
                    stuck = int(sa0[sl].sum()) + int(sa1[sl].sum())
                    if cells and stuck / cells >= threshold:
                        sa0[sl] = False
                        sa1[sl] = False
                        self._retired.add(tile)
                        newly += 1
        if newly:
            self.fault_version += 1
        return newly

    def fault_state(self) -> dict:
        return {
            "clock": self._clock,
            "drift_mult": self._drift_mult_q,
            "fault_version": self.fault_version,
            "retired_tiles": self.retired_tiles,
            "stuck_cells": self.stuck_cell_count(),
            "overrides": dict(self._overrides),
        }

    # -- faulty compute dispatch --------------------------------------------

    def _weight_faults_active(self) -> bool:
        return self.fault.stuck_rate > 0.0 or self._drift_mult_q != 1.0

    def _faulty_weights(self, w):
        """Perturb crossbar weights as the devices would read back: drift
        first (multiplicative in conductance space), stuck cells override.
        The normalization scale is the ORIGINAL max|w| so stuck cells land
        exactly on w_min/w_max in device units."""
        if not self._weight_faults_active():
            return w
        import jax
        import jax.numpy as jnp

        from repro.core import physics as P

        dp = P.DeviceParams()
        s = jax.lax.stop_gradient(
            jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
        )
        wn = w / s
        m = self._drift_mult_q
        if m != 1.0:
            wn = P.weight_from_conductance(
                m * P.weight_to_conductance(wn, dp), dp
            )
        sa0, sa1 = self._stuck_masks(tuple(w.shape))
        if sa0 is not None:
            wn = jnp.where(sa0, dp.w_min, wn)
            wn = jnp.where(sa1, dp.w_max, wn)
        return (wn * s).astype(w.dtype)

    def wta_readout_params(self, vth0: float, sigma_z: float):
        return (
            vth0 + self._knob("comparator_offset"),
            sigma_z * (1.0 + self._knob("read_sigma_inflation")),
        )

    def crossbar_mac(self, x, w, key, cfg, binarize=True):
        from repro.kernels import ops

        infl = self._knob("read_sigma_inflation")
        off = self._knob("comparator_offset")
        if not (self._weight_faults_active() or infl or off):
            return ops.crossbar_mac_sim(x, w, key, cfg, binarize)
        w = self._faulty_weights(w)
        if infl:
            if cfg.calibrated and binarize:
                cfg = dataclasses.replace(cfg, beta=cfg.beta / (1.0 + infl))
            elif cfg.calibrated:
                cfg = dataclasses.replace(
                    cfg, linear_sigma=cfg.linear_sigma * (1.0 + infl)
                )
            else:
                dev = cfg.device.replace(
                    temperature=cfg.device.temperature * (1.0 + infl) ** 2
                )
                cfg = dataclasses.replace(cfg, device=dev)
        y = ops.crossbar_mac_sim(x, w, key, cfg, binarize)
        if off and not binarize:
            y = y + off
        return y

    def wta_counts(self, z, key, *, n_trials, vth0, sigma_z):
        from repro.kernels import ops

        vth0, sigma_z = self.wta_readout_params(vth0, sigma_z)
        return ops.wta_counts_sim(
            z, key, n_trials=n_trials, vth0=vth0, sigma_z=sigma_z
        )

    # stoch_round / stoch_round_serving / paged_(prefill_)attention are
    # digital-domain ops (counters, SRAM attention) — inherited sim paths.


BACKENDS = {"sim": SimBackend, "sim_faulty": FaultySimBackend}

_ACTIVE: DeviceBackend = SimBackend()


def make_backend(
    name: str, model_cfg: Optional[Any] = None, **kw
) -> DeviceBackend:
    """Instantiate a registered backend (loud on unknown names).

    Extra keyword arguments are forwarded to the backend constructor —
    e.g. ``make_backend("sim_faulty", cfg, fault=FaultConfig(...))``."""
    if name not in BACKENDS:
        raise ValueError(
            f"unknown device backend {name!r}; registered: "
            f"{sorted(BACKENDS)}"
        )
    return BACKENDS[name](model_cfg, **kw)


def get_backend() -> DeviceBackend:
    """The process-wide backend ops.py routes kernel calls through."""
    return _ACTIVE


def set_backend(backend: DeviceBackend) -> DeviceBackend:
    """Install a backend process-wide; returns the previous one.

    Affects the NEXT trace of any jitted caller — already-compiled
    artifacts keep the math they were traced with."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = backend
    return prev


@contextlib.contextmanager
def use_backend(backend: DeviceBackend):
    """Exception-safe scoped install: the previous process-wide backend is
    restored on exit no matter how the body leaves, so a failing test (or
    a raising engine tick) can't leak a faulty backend into later work."""
    prev = set_backend(backend)
    try:
        yield backend
    finally:
        set_backend(prev)
