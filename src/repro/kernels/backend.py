"""Pluggable device-backend seam behind the analog kernels.

The public wrappers in :mod:`repro.kernels.ops` no longer call their
implementations directly — they dispatch through the process-wide active
:class:`DeviceBackend`.  The split mirrors daffodil-lib's
``Daffodil_Base`` / ``Sim`` / ``Phys`` layering:

* :class:`SimBackend` (the default) routes every kernel to today's
  Pallas/jnp math unchanged — same compiled artifacts, same bits — and
  carries **pure accounting**: host-side tallies of the analog events a
  served workload drives (crossbar MAC tile-reads, comparator decisions,
  input-DAC conversions, stochastic-rounding events), priced by the
  calibrated Table I constants in :mod:`repro.core.cost_model`.
* A future ``PhysBackend`` would override the compute methods with
  hardware-in-the-loop calls (chip driver, FPGA harness) while inheriting
  the same accounting surface — the seam is the point of this module.

Two usage planes, deliberately separate:

1. **Compute dispatch** (trace-time, inside ``jit``): ``ops.crossbar_mac``
   etc. call ``get_backend().crossbar_mac(...)``.  Swapping the process
   backend with :func:`set_backend` swaps the math everywhere at the next
   trace.
2. **Event accounting** (host-side): events cannot be counted inside a
   traced computation, and the counts must not depend on compiled-shape
   padding — so the serving engine owns a private backend instance per
   engine (``ServeConfig.device_backend`` names it) and notes analytical
   multiplicities per entry-point call (see
   ``launch/specs.analog_call_profile``).  Counts are therefore exact
   invariants: ``totals == tokens_computed x per-token shape counts``,
   pinned by tests/test_energy_accounting.py.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core import cost_model as CM


class DeviceBackend:
    """Base: accounting surface (shared) + abstract compute dispatch."""

    name = "base"

    def __init__(self, model_cfg: Optional[Any] = None):
        self.model_cfg = model_cfg
        if model_cfg is not None:
            self._per_tok = CM.per_token_analog_counts(model_cfg)
            self._per_sample = CM.per_sample_analog_counts(model_cfg)
            self._per_kv_tok = CM.per_kv_token_round_events(model_cfg)
        else:
            zero = CM.AnalogOpCounts()
            self._per_tok = self._per_sample = self._per_kv_tok = zero
        self.reset()

    # -- accounting (host-side, engine-driven) ------------------------------

    def reset(self) -> None:
        self._counts = CM.AnalogOpCounts()
        self._tokens = {"prefill": 0, "decode": 0, "draft": 0}
        self._sample_events = 0
        self._kv_written_tokens = 0

    def note_call(self, profile: dict) -> None:
        """Record one device entry-point invocation.

        ``profile`` is ``launch/specs.analog_call_profile(...)`` output:
        token-forward multiplicities per kind, sampling events, and
        KV-writing tokens.  Counts accumulate as exact integer multiples
        of the per-token/per-sample/per-KV-token shape counts."""
        fwd = 0
        for kind in ("prefill", "decode", "draft"):
            n = profile[kind]
            self._tokens[kind] += n
            fwd += n
        self._sample_events += profile["samples"]
        self._kv_written_tokens += profile["kv_tokens"]
        self._counts = (
            self._counts
            + self._per_tok.scaled(fwd)
            + self._per_sample.scaled(profile["samples"])
            + self._per_kv_tok.scaled(profile["kv_tokens"])
        )

    def events(self) -> CM.AnalogOpCounts:
        return self._counts

    def tokens_computed(self) -> dict:
        out = dict(self._tokens)
        out["total"] = sum(self._tokens.values())
        return out

    def snapshot(self, published_tokens: int = 0) -> dict:
        """Full accounting report: tallies, per-event shape counts (so a
        validator can re-derive the totals from the artifact alone), and
        Table I pricing under both readout schemes."""
        c = self._counts
        prices = CM.price_counts(c)
        denom = max(published_tokens, 1)

        def scheme(energy_pj: float) -> dict:
            return {
                "energy_pj_gross": energy_pj,
                "energy_pj_per_token": energy_pj / denom,
                "tops_per_w_effective": CM.effective_tops_per_w(
                    c, energy_pj
                ),
            }

        return {
            "backend": self.name,
            "tokens_computed": self.tokens_computed(),
            "tokens_published": published_tokens,
            "sample_events": self._sample_events,
            "kv_written_tokens": self._kv_written_tokens,
            "counts": c.as_dict(),
            "per_token_counts": self._per_tok.as_dict(),
            "per_sample_counts": self._per_sample.as_dict(),
            "per_kv_token_counts": self._per_kv_tok.as_dict(),
            "raca": scheme(prices["raca_energy_pj"]),
            "adc1b": scheme(prices["adc1b_energy_pj"]),
        }

    # -- compute dispatch (trace-time) --------------------------------------

    def crossbar_mac(self, x, w, key, cfg, binarize=True):
        raise NotImplementedError

    def wta_counts(self, z, key, *, n_trials, vth0, sigma_z):
        raise NotImplementedError

    def stoch_round(self, x, key, *, step, lo, hi):
        raise NotImplementedError

    def stoch_round_serving(self, x, seed, *, step, lo, hi):
        raise NotImplementedError

    def paged_attention(self, q, k_pages, v_pages, table, pos, **kw):
        raise NotImplementedError

    def paged_prefill_attention(self, q, k_pages, v_pages, table, q0, **kw):
        raise NotImplementedError


class SimBackend(DeviceBackend):
    """Default backend: today's Pallas/jnp math, accounting only.

    Compute methods delegate to the ``*_sim`` implementations in ops.py
    (imported lazily — ops imports this module at load).  The math is
    bit-identical to the pre-seam wrappers; the recompile-guard and
    byte-identity suites run through this path."""

    name = "sim"

    def crossbar_mac(self, x, w, key, cfg, binarize=True):
        from repro.kernels import ops

        return ops.crossbar_mac_sim(x, w, key, cfg, binarize)

    def wta_counts(self, z, key, *, n_trials, vth0, sigma_z):
        from repro.kernels import ops

        return ops.wta_counts_sim(
            z, key, n_trials=n_trials, vth0=vth0, sigma_z=sigma_z
        )

    def stoch_round(self, x, key, *, step, lo, hi):
        from repro.kernels import ops

        return ops.stoch_round_sim(x, key, step=step, lo=lo, hi=hi)

    def stoch_round_serving(self, x, seed, *, step, lo, hi):
        from repro.kernels import ops

        return ops.stoch_round_serving_sim(x, seed, step=step, lo=lo, hi=hi)

    def paged_attention(self, q, k_pages, v_pages, table, pos, **kw):
        from repro.kernels import ops

        return ops.paged_attention_sim(q, k_pages, v_pages, table, pos, **kw)

    def paged_prefill_attention(self, q, k_pages, v_pages, table, q0, **kw):
        from repro.kernels import ops

        return ops.paged_prefill_attention_sim(
            q, k_pages, v_pages, table, q0, **kw
        )


BACKENDS = {"sim": SimBackend}

_ACTIVE: DeviceBackend = SimBackend()


def make_backend(name: str, model_cfg: Optional[Any] = None) -> DeviceBackend:
    """Instantiate a registered backend (loud on unknown names)."""
    if name not in BACKENDS:
        raise ValueError(
            f"unknown device backend {name!r}; registered: "
            f"{sorted(BACKENDS)}"
        )
    return BACKENDS[name](model_cfg)


def get_backend() -> DeviceBackend:
    """The process-wide backend ops.py routes kernel calls through."""
    return _ACTIVE


def set_backend(backend: DeviceBackend) -> DeviceBackend:
    """Install a backend process-wide; returns the previous one.

    Affects the NEXT trace of any jitted caller — already-compiled
    artifacts keep the math they were traced with."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = backend
    return prev
