"""Logical-axis sharding context used by model code.

Models annotate activations with *logical* axis names
(``shard(x, ("batch", "seq", "embed"))``); the launcher installs a mesh and a
logical→mesh translation table (launch/sharding.py).  Outside any context the
annotation is a no-op, so the same model code runs on one CPU device and on a
512-chip production mesh unchanged.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_axis_rules", default=None
)


def current() -> Optional[tuple]:
    return _CTX.get()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Mapping[str, Optional[object]]):
    """Install (mesh, logical→mesh table) for shard() annotations.

    ``rules`` maps a logical axis name to a mesh axis name, a tuple of mesh
    axis names, or None (replicated)."""
    token = _CTX.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _CTX.reset(token)


def spec_for(names: Sequence[Optional[str]]) -> P:
    """Translate logical axis names to a PartitionSpec under current rules."""
    ctx = _CTX.get()
    if ctx is None:
        return P()
    _, rules = ctx
    return P(*[rules.get(n) if n is not None else None for n in names])


def shard(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Constrain activation sharding by logical names; no-op with no context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    if len(names) != x.ndim:
        raise ValueError(f"rank mismatch: {names} vs {x.shape}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(names))
    )


def sharding_for(names: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    ctx = _CTX.get()
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, spec_for(names))
