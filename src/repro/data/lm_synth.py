"""Stateless synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) — there is no iterator
state to checkpoint, which makes the pipeline trivially fault-tolerant,
elastic (any shard count re-partitions the same stream) and reproducible
across restarts: exactly the property large fleets need.

The stream is a Markov-zipf language: with probability q the next token is a
deterministic successor (learnable structure: loss decreases), otherwise a
zipf-distributed draw (heavy-tail noise floor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _tokens(key, b: int, s: int, vocab: int, markov_p: float = 0.75):
    k1, k2, k3 = jax.random.split(key, 3)
    # zipf-ish via log-uniform
    u = jax.random.uniform(k1, (b, s + 1))
    zipf = jnp.exp(u * jnp.log(float(vocab))).astype(jnp.int32) % vocab
    follow = jax.random.uniform(k2, (b, s + 1)) < markov_p

    def step(prev, xs):
        z, f = xs
        succ = (prev * 31 + 17) % vocab
        tok = jnp.where(f, succ, z)
        return tok, tok

    init = zipf[:, 0]
    _, toks = jax.lax.scan(
        step, init, (zipf[:, 1:].T, follow[:, 1:].T)
    )
    toks = jnp.concatenate([init[:, None], toks.T], axis=1)  # (B, S+1)
    return toks


def lm_batch(
    cfg,
    *,
    batch: int,
    seq: int,
    step: int,
    seed: int = 0,
    shard: int = 0,
    n_shards: int = 1,
) -> dict:
    """Batch for one (step, shard).  Shards draw disjoint streams."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), step), shard
    )
    toks = _tokens(key, batch, seq, cfg.vocab)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        kp = jax.random.fold_in(key, 101)
        out["patches"] = (
            jax.random.normal(kp, (batch, cfg.n_patches, cfg.d_model)) * 0.02
        )
    if cfg.family == "encdec":
        kf = jax.random.fold_in(key, 102)
        out["frames"] = (
            jax.random.normal(kf, (batch, seq, cfg.d_model)) * 0.1
        )
    return out
