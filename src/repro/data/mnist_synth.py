"""Procedural MNIST surrogate (offline container — no dataset downloads).

Renders 28×28 digit images from 7×5 bitmap glyphs with random affine
distortion (shift/scale/shear), per-pixel Gaussian noise and a light blur.
Same shapes/classes as MNIST ([784] in [0,1], 10 classes); task difficulty
is comparable (a linear probe gets ~90%, the paper's FCNN >96% — see
EXPERIMENTS.md §Reproduction for the validation protocol).

Fully deterministic from (seed, step, shard): stateless like lm_synth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_GLYPHS_TXT = [
    # 0
    "01110 10001 10011 10101 11001 10001 01110",
    # 1
    "00100 01100 00100 00100 00100 00100 01110",
    # 2
    "01110 10001 00001 00110 01000 10000 11111",
    # 3
    "11110 00001 00001 01110 00001 00001 11110",
    # 4
    "00010 00110 01010 10010 11111 00010 00010",
    # 5
    "11111 10000 11110 00001 00001 10001 01110",
    # 6
    "00110 01000 10000 11110 10001 10001 01110",
    # 7
    "11111 00001 00010 00100 01000 01000 01000",
    # 8
    "01110 10001 10001 01110 10001 10001 01110",
    # 9
    "01110 10001 10001 01111 00001 00010 01100",
]


def _glyphs() -> np.ndarray:
    out = np.zeros((10, 7, 5), np.float32)
    for d, rows in enumerate(_GLYPHS_TXT):
        for r, row in enumerate(rows.split()):
            for c, ch in enumerate(row):
                out[d, r, c] = float(ch == "1")
    return out


_GLYPH_ARR = jnp.asarray(_glyphs())


def _render(key, labels: jax.Array) -> jax.Array:
    """Render a batch of distorted digits.  labels: (B,) -> (B, 28, 28)."""
    b = labels.shape[0]
    ks = jax.random.split(key, 5)
    # sample affine params
    scale = jax.random.uniform(ks[0], (b,), minval=2.2, maxval=3.2)
    shear = jax.random.uniform(ks[1], (b,), minval=-0.25, maxval=0.25)
    dx = jax.random.uniform(ks[2], (b,), minval=-3.5, maxval=3.5)
    dy = jax.random.uniform(ks[3], (b,), minval=-3.5, maxval=3.5)

    yy, xx = jnp.meshgrid(
        jnp.arange(28, dtype=jnp.float32),
        jnp.arange(28, dtype=jnp.float32),
        indexing="ij",
    )

    def one(lab, sc, sh, ddx, ddy):
        # inverse-map output pixels into glyph coordinates
        gy = (yy - 14.0 - ddy) / sc + 3.5
        gx = (xx - 14.0 - ddx) / sc - sh * (gy - 3.5) + 2.5
        gyi = jnp.clip(jnp.round(gy).astype(jnp.int32), 0, 6)
        gxi = jnp.clip(jnp.round(gx).astype(jnp.int32), 0, 4)
        inside = (gy >= -0.5) & (gy <= 6.5) & (gx >= -0.5) & (gx <= 4.5)
        img = _GLYPH_ARR[lab][gyi, gxi] * inside
        return img

    imgs = jax.vmap(one)(labels, scale, shear, dx, dy)
    # light blur (3x3 box) + noise
    pad = jnp.pad(imgs, ((0, 0), (1, 1), (1, 1)))
    blur = sum(
        pad[:, i : i + 28, j : j + 28] for i in range(3) for j in range(3)
    ) / 9.0
    imgs = 0.6 * imgs + 0.4 * blur
    noise = jax.random.normal(ks[4], imgs.shape) * 0.12
    return jnp.clip(imgs + noise, 0.0, 1.0)


def mnist_batch(
    *, batch: int, step: int, seed: int = 0, shard: int = 0
) -> dict:
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), step), shard
    )
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, 10)
    imgs = _render(k2, labels)
    return {"image": imgs.reshape(batch, 784), "label": labels}


def mnist_dataset(n: int, seed: int = 1234) -> dict:
    """A fixed evaluation set (held out from training by seed)."""
    return mnist_batch(batch=n, step=0, seed=seed)
