from .lm_synth import lm_batch
from .mnist_synth import mnist_batch, mnist_dataset

__all__ = ["lm_batch", "mnist_batch", "mnist_dataset"]
