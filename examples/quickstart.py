"""Quickstart: the paper's circuit in 60 lines.

Builds a stochastic binary Sigmoid neuron layer and a WTA SoftMax readout
from the public API, shows the calibration that makes thermal noise act as
the activation function, and classifies a batch with majority voting.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    AnalogConfig,
    DeviceParams,
    analog_matmul,
    calibrate_v_read,
    effective_beta,
    wta_head,
)

# --- 1. Calibrate the device so the comparator IS a sigmoid (Eq. 13) -------
N_INPUTS = 784
dp = calibrate_v_read(DeviceParams(), n_rows=N_INPUTS)
print(f"calibrated read voltage V_r = {dp.v_read * 1e3:.2f} mV")
print(f"effective logistic slope beta = {effective_beta(dp, N_INPUTS):.4f}")

cfg = AnalogConfig(mode="analog_stochastic", device=dp, use_pallas="auto")

# --- 2. A crossbar layer: MAC + thermal noise + comparator, no ADC ---------
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (N_INPUTS, 256)) * 0.05
x = (jax.random.uniform(jax.random.PRNGKey(1), (32, N_INPUTS)) < 0.3
     ).astype(jnp.float32)

k1, k2 = jax.random.split(jax.random.PRNGKey(2))
binary_hidden = analog_matmul(cfg, k1, x, w)
print("hidden activations are binary:",
      sorted(set(jnp.unique(binary_hidden).tolist())))
print("mean fire rate:", float(binary_hidden.mean()))

# expectation matches the logistic of the (conductance-quantized)
# pre-activation:
from repro.core.crossbar import quantize_weights

p_emp = jnp.stack([
    analog_matmul(cfg, k, x, w)
    for k in jax.random.split(k2, 256)
]).mean(0)
p_ideal = jax.nn.sigmoid(x @ quantize_weights(w, dp))
print("E[comparator] vs sigmoid, max err:",
      float(jnp.max(jnp.abs(p_emp - p_ideal))))

# --- 3. WTA SoftMax readout: votes, no exponentials ------------------------
logits = jax.random.normal(jax.random.PRNGKey(3), (4, 10))
res = wta_head(cfg, jax.random.PRNGKey(4), logits)
print("WTA vote shares:", jnp.round(res.probs[0], 3))
print("softmax        :", jnp.round(jax.nn.softmax(logits[0]), 3))
print("prediction agreement:",
      bool(jnp.all(jnp.argmax(res.counts, -1)
                   == jnp.argmax(logits, -1))))
