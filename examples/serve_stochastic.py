"""Serve a small LM with batched requests, comparing the digital greedy
sampler against the paper's WTA stochastic SoftMax sampling head (votes of
noisy comparator trials pick each token).

    PYTHONPATH=src python examples/serve_stochastic.py
"""

import dataclasses
import time

import jax

from repro.configs import get_smoke_config
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine


def main():
    base = get_smoke_config("stablelm-3b")
    cfg = dataclasses.replace(base, n_layers=4, d_model=128, d_ff=256,
                              n_heads=4, n_kv_heads=4, d_head=32,
                              max_seq=256)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)

    prompts = [[11, 42, 7], [3, 3, 3, 3], [250, 1, 99, 5, 17], [8]]

    for mode, wta in (("greedy (digital argmax)", False),
                      ("WTA stochastic votes (RACA)", True)):
        mcfg = dataclasses.replace(cfg, wta_head=wta)
        eng = ServingEngine(
            params, mcfg,
            ServeConfig(max_batch=4, max_new_tokens=16, max_len=128),
        )
        for p in prompts:
            eng.submit(p)
        t0 = time.time()
        outs = eng.step()
        dt = time.time() - t0
        print(f"--- {mode} ({dt:.2f}s for {len(prompts)} requests) ---")
        for p, o in zip(prompts, outs):
            print(f"  prompt={p} -> {o}")


if __name__ == "__main__":
    main()
