"""Serve a small LM with continuous batching, comparing the digital greedy
sampler against the paper's WTA stochastic SoftMax sampling head (votes of
noisy comparator trials pick each token, independently per slot).

Four requests with different prompt lengths and token budgets share three
decode slots: the scheduler prefills each arrival into a free slot of the
live batch and refills slots as short requests finish.

``--kv-dtype int8`` switches the paged KV pool to stochastically rounded
int8 codes + scale planes — half the decode HBM bytes per token, with
dequantization fused into the attention math.

Two of the requests share an identical prompt: with prefix sharing (on by
default) the repeat maps the resident prompt blocks through the
content-hash index and skips its bucket prefill entirely — the summary
line counts the hits.  ``--no-prefix-sharing`` turns the dedup off.

``--priority 0`` submits the requests as the interactive class (which may
preempt lower-priority work under pool pressure — inert here with a single
class) and ``--deadline-ms N`` stamps a per-request SLO: a request past it
is evicted with reason ``"deadline"``, counted in the summary line.

``--speculate-k K`` turns on self-speculative decoding: every tick each
decoding slot drafts K tokens with the fused decode step and verifies the
run in one read-only pass — greedy output is byte-identical to plain
decode, and the summary line reports the acceptance rate.

    PYTHONPATH=src python examples/serve_stochastic.py [--kv-dtype int8]
        [--no-prefix-sharing] [--priority 0] [--deadline-ms 500]
        [--speculate-k 4]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.models import get_model_fns
from repro.serving import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--kv-dtype", choices=("same", "int8"), default="same",
        help="KV cache dtype; 'int8' = stochastic-rounded quantized pool",
    )
    ap.add_argument(
        "--no-prefix-sharing", action="store_true",
        help="disable content-hash prompt-block sharing (COW paged pool)",
    )
    ap.add_argument(
        "--priority", type=int, default=1,
        help="priority class for every request: 0 = interactive (preempts "
             "lower classes under pool pressure), 1 = batch (default)",
    )
    ap.add_argument(
        "--deadline-ms", type=float, default=None,
        help="per-request deadline in ms; past it the engine evicts with "
             "reason 'deadline' (default: none)",
    )
    ap.add_argument(
        "--speculate-k", type=int, default=0,
        help="self-speculative decoding: draft K tokens per tick, verify "
             "in one read-only pass, roll back at the first mismatch "
             "(0 = off; greedy output is byte-identical either way)",
    )
    args = ap.parse_args()

    base = get_smoke_config("stablelm-3b")
    cfg = dataclasses.replace(base, n_layers=4, d_model=128, d_ff=256,
                              n_heads=4, n_kv_heads=4, d_head=32,
                              max_seq=256, kv_cache_dtype=args.kv_dtype)
    fns = get_model_fns(cfg)
    params = fns.init(jax.random.PRNGKey(0), cfg)

    requests = [  # (prompt, max_new_tokens) — mixed lengths and budgets,
        ([11, 42, 7], 16),      # with one repeated prompt so the prefix
        ([3, 3, 3, 3], 6),      # index has something to dedup
        ([250, 1, 99, 5, 17], 12),
        ([11, 42, 7], 8),
    ]

    for mode, wta in (("greedy (digital argmax)", False),
                      ("WTA stochastic votes (RACA)", True)):
        mcfg = dataclasses.replace(cfg, wta_head=wta)
        eng = ServingEngine(
            params, mcfg,
            ServeConfig(
                max_batch=3, max_new_tokens=16, max_len=128,
                # block == smallest bucket: short prompts fill whole
                # blocks, so the repeated prompt can share them while the
                # original is still decoding
                kv_block_size=8,
                enable_prefix_sharing=not args.no_prefix_sharing,
                speculate_k=args.speculate_k,
            ),
        )
        rids = [
            eng.submit(
                p, n, priority=args.priority, deadline_ms=args.deadline_ms
            )
            for p, n in requests
        ]
        outs = eng.run()
        m = eng.metrics()
        print(f"--- {mode} (kv_cache_dtype={args.kv_dtype}) ---")
        for rid, (p, _) in zip(rids, requests):
            print(f"  prompt={p} -> {outs.get(rid, [])}")
        print(
            f"  {m.completed} requests, {m.total_tokens} tokens: "
            f"{m.tokens_per_s:.1f} tok/s, "
            f"ttft {m.ttft_mean * 1e3:.0f}ms (p99 {m.ttft_p99 * 1e3:.0f}ms), "
            f"occupancy {m.occupancy_mean:.2f} "
            f"over {m.decode_steps} decode steps; "
            f"{m.prefills} prefills ({m.prefix_hits} prefix hits, "
            f"{m.prefix_partial_hits} partial hits, "
            f"{m.cow_forks} COW forks; "
            f"{m.prefill_tokens} prefill tokens computed, "
            f"{m.prefill_tokens_saved} saved); "
            f"{m.preemptions} preemptions, "
            f"evictions {m.evictions or '{}'}"
        )
        if m.spec_rounds:
            print(
                f"  speculative: {m.spec_rounds} rounds, acceptance "
                f"{m.spec_acceptance:.2f}, "
                f"{m.spec_tokens_per_round:.2f} tokens/round"
            )


if __name__ == "__main__":
    main()
