"""End-to-end driver: train the paper's FCNN [784, 500, 300, 10] with
stochastic-binary neurons (noise-aware QAT) on the MNIST surrogate, then
evaluate the full RACA inference pipeline (Fig. 6 protocol), through the
fault-tolerant training loop (checkpoints + resume).

    PYTHONPATH=src python examples/train_mnist_raca.py \
        [--steps 300] [--small] [--ckpt-dir ckpts/fcnn]
"""

import argparse
import dataclasses
import logging

import jax
import numpy as np

from repro.configs.fcnn_mnist import CONFIG as FCNN_CFG
from repro.data import mnist_batch, mnist_dataset
from repro.models.fcnn import fcnn_predict_digital, fcnn_predict_raca
from repro.optim import AdamWConfig
from repro.train import TrainConfig
from repro.train.loop import LoopConfig, run

logging.basicConfig(level=logging.INFO, format="%(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--small", action="store_true",
                    help="reduced hidden widths (fast CPU run)")
    ap.add_argument("--ckpt-dir", default="ckpts/fcnn")
    args = ap.parse_args()

    cfg = FCNN_CFG
    if args.small:
        cfg = dataclasses.replace(cfg, fcnn_layers=(784, 128, 64, 10))
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=3e-3, state_dtype="float32",
                        stochastic_rounding=False),
        total_steps=args.steps,
    )
    lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=20)

    state, stats = run(
        cfg, tcfg, lcfg,
        batch_fn=lambda step: mnist_batch(batch=args.batch, step=step),
    )
    print(f"trained {args.steps} steps; restarts={stats['restarts']} "
          f"stragglers={stats['stragglers']}")

    test = mnist_dataset(1024)
    y = np.asarray(test["label"])
    digital = float(
        (np.asarray(fcnn_predict_digital(state.params, test["image"], cfg))
         == y).mean())
    print(f"digital baseline accuracy: {digital:.4f}")
    for votes in (1, 4, 16, 64):
        pred = fcnn_predict_raca(
            state.params, test["image"], cfg, jax.random.PRNGKey(7), votes
        )
        acc = float((np.asarray(pred) == y).mean())
        print(f"RACA stochastic inference, {votes:3d} votes: acc={acc:.4f}")


if __name__ == "__main__":
    main()
