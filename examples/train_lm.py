"""Train a ~100M-parameter LM (scaled-down stablelm family) for a few
hundred steps on the synthetic Markov-zipf stream, optionally with the
paper's analog-stochastic MLP neurons (noise-aware QAT for RACA deploy).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --analog
"""

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config
from repro.core.analog import AnalogConfig
from repro.core.physics import DeviceParams, calibrate_v_read
from repro.data import lm_batch
from repro.optim import AdamWConfig
from repro.train import TrainConfig
from repro.train.loop import LoopConfig, run

logging.basicConfig(level=logging.INFO, format="%(message)s")


def small_lm(analog: bool):
    """~100M-param member of the stablelm family."""
    cfg = get_config("stablelm-3b")
    cfg = dataclasses.replace(
        cfg,
        name="stablelm-100m",
        n_layers=8,
        d_model=640,
        n_heads=8,
        n_kv_heads=8,
        d_head=80,
        d_ff=1728,
        vocab=50304,
        max_seq=2048,
        dtype="float32",
    )
    if analog:
        cfg = dataclasses.replace(
            cfg,
            analog=AnalogConfig(
                mode="analog_stochastic",
                device=calibrate_v_read(DeviceParams(), cfg.d_model),
                use_pallas="auto",
            ),
        )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--analog", action="store_true",
                    help="RACA analog-stochastic MLP neurons (QAT)")
    ap.add_argument("--ckpt-dir", default="ckpts/lm")
    args = ap.parse_args()

    cfg = small_lm(args.analog)
    n = cfg.param_count()
    print(f"model: {cfg.name} ({n / 1e6:.1f}M params, "
          f"analog={cfg.analog.mode})")
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=3e-4),
        total_steps=args.steps,
        warmup_steps=20,
    )
    lcfg = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=100, log_every=10)
    state, stats = run(
        cfg, tcfg, lcfg,
        batch_fn=lambda step: lm_batch(
            cfg, batch=args.batch, seq=args.seq, step=step
        ),
    )
    losses = stats["losses"]
    first = sum(l for _, l in losses[:10]) / max(len(losses[:10]), 1)
    last = sum(l for _, l in losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"loss: first10={first:.4f} last10={last:.4f} "
          f"(improved {first - last:+.4f})")


if __name__ == "__main__":
    main()
